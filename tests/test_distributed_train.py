"""Distributed numerics: sharded paths must equal single-device math.

The dry-run proves the production mesh *compiles*; these tests prove the
sharded programs *compute the same thing* (8-device subprocess meshes).
"""

from __future__ import annotations

import os
import subprocess
import sys

import pytest

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.moe import MoEConfig, init_moe, moe_ffn
from repro.distributed.sharding import ShardCtx

cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, n_shared=1, capacity_factor=4.0)
params = init_moe(jax.random.key(0), 32, cfg, dtype=jnp.float32)
x = jax.random.normal(jax.random.key(1), (16, 32), jnp.float32)

y_local, aux_local = moe_ffn(params, x, cfg, None)

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
y_sh, aux_sh = jax.jit(lambda p, xx: moe_ffn(p, xx, cfg, ctx))(params, x)

err = float(jnp.max(jnp.abs(y_sh - y_local)))
rel = err / (float(jnp.max(jnp.abs(y_local))) + 1e-9)
# capacity_factor=4 -> no drops in either path -> outputs match tightly.
assert rel < 1e-5, rel
# Aux loss is per-data-shard-then-averaged (standard DP semantics) — it is
# nonlinear in the token set, so only statistical closeness is expected.
assert np.isfinite(float(aux_sh)) and float(aux_sh) > 0
assert abs(float(aux_sh) - float(aux_local)) / max(float(aux_local), 1e-9) < 0.5
print("MOE_EP_OK", rel)

# Decode-time full-grid EP must match too.
from repro.models.moe import moe_ffn_decode_ep_all
y_ep, _ = jax.jit(lambda p, xx: moe_ffn_decode_ep_all(p, xx, cfg, ctx))(params, x)
rel2 = float(jnp.max(jnp.abs(y_ep - y_local))) / (float(jnp.max(jnp.abs(y_local))) + 1e-9)
assert rel2 < 1e-5, rel2
print("MOE_EP_ALL_OK", rel2)
"""

_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.registry import get_arch
from repro.distributed.sharding import ShardCtx
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.trainer import zero1_state_specs

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
arch = get_arch("qwen3-4b")
cfg = arch.model_config(reduced=True)
params = arch.init_params(jax.random.key(0), cfg)
step, kind = arch.build_step(cfg, "train_4k", shard_ctx=None)
opt = init_opt_state(params, AdamWConfig())
batch = arch.make_batch(cfg, "train_4k", seed=0)

# Single-device reference step.
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# Mesh-sharded step: params TP-sharded, ZeRO-1 opt state, batch over data.
p_specs = arch.param_pspecs(cfg, params)
params_sh = jax.device_put(
    params, jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                         is_leaf=lambda x: isinstance(x, P)))
o_specs = zero1_state_specs(p_specs, params, opt, 2, ("data",),
                            mesh_shape=dict(mesh.shape))
opt_sh = jax.device_put(
    opt, jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                      is_leaf=lambda x: isinstance(x, P)))
b_specs = arch.batch_pspecs(cfg, "train_4k", ctx)
batch_sh = jax.device_put(
    batch, jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                        is_leaf=lambda x: isinstance(x, P)))
p2, o2, m2 = jax.jit(step)(params_sh, opt_sh, batch_sh)

l1, l2 = float(m1["loss"]), float(m2["loss"])
assert abs(l1 - l2) / max(abs(l1), 1e-9) < 1e-4, (l1, l2)
d1 = np.asarray(jax.device_get(p1["embed"]))
d2 = np.asarray(jax.device_get(p2["embed"]))
np.testing.assert_allclose(d1, d2, rtol=2e-4, atol=2e-5)
print("TRAIN_SHARDED_OK", l1, l2)
"""


def _run(code: str, marker: str, timeout=900):
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT, timeout=timeout,
    )
    assert marker in out.stdout, out.stdout[-1500:] + out.stderr[-2500:]


@pytest.mark.slow
def test_moe_sharded_matches_local():
    _run(_MOE, "MOE_EP_ALL_OK")


@pytest.mark.slow
def test_train_step_sharded_matches_single_device():
    _run(_TRAIN, "TRAIN_SHARDED_OK", timeout=1200)
