"""Tier-1 wrapper for the docs cross-reference gate.

The real checker is ``.github/check_doc_links.py`` (also a CI step);
running it here means a dangling ``DESIGN.md §N`` citation or a broken
relative markdown link fails the local suite before it ever reaches CI.
"""

from __future__ import annotations

import os
import subprocess
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_design_sections_and_markdown_links_resolve():
    out = subprocess.run(
        [sys.executable, os.path.join(".github", "check_doc_links.py")],
        capture_output=True,
        text=True,
        cwd=_REPO_ROOT,
        timeout=120,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.startswith("OK:"), out.stdout
