"""Deliverable guard: every (arch x shape x mesh) cell has a passing
dry-run artifact (skipped in fresh checkouts before `dryrun --all`)."""

from __future__ import annotations

import json
import os

import pytest

from repro.configs.registry import all_cells

RESULTS = os.path.join("benchmarks", "results", "dryrun")


@pytest.mark.skipif(
    not os.path.isdir(RESULTS) or not os.listdir(RESULTS),
    reason="dry-run results not generated (run repro.launch.dryrun --all)",
)
def test_every_cell_compiled_on_both_meshes():
    missing, failed = [], []
    for arch, shape, info in all_cells():
        for mesh in ("single", "multi"):
            path = os.path.join(RESULTS, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                missing.append((arch, shape, mesh))
                continue
            d = json.load(open(path))
            if not d.get("ok"):
                failed.append((arch, shape, mesh, d.get("error", "")[:80]))
    assert not missing, f"missing dry-run cells: {missing}"
    assert not failed, f"failed dry-run cells: {failed}"


@pytest.mark.skipif(
    not os.path.isdir(RESULTS) or not os.listdir(RESULTS),
    reason="dry-run results not generated",
)
def test_perf_variants_present_and_fit_hbm():
    """§Perf optimized variants exist and fit the 16 GiB v5e budget."""
    cells = [
        ("deepseek-67b__decode_32k__single__v-split_kv.json", 16.0),
        ("deepseek-v3-671b__decode_32k__single__v-split_kv.json", 16.0),
        ("deepseek-67b__prefill_32k__single__v-split_kv.json", 16.0),
        ("graphsage-reddit__ogb_products__single__v-sharded.json", 16.0),
        ("anytime-ir__serve_anytime__single__v-i8.json", 16.0),
        ("deepseek-v3-671b__train_4k__single.json", 16.0),
    ]
    for name, budget_gib in cells:
        path = os.path.join(RESULTS, name)
        assert os.path.exists(path), f"missing variant artifact: {name}"
        d = json.load(open(path))
        assert d.get("ok"), name
        peak = d["memory"].get("peak_memory_in_bytes", 0) / 2**30
        assert peak <= budget_gib, f"{name}: {peak:.1f} GiB > {budget_gib}"
