"""Sharded full-batch GraphSAGE (§Perf cell B) must match the baseline."""

from __future__ import annotations

import os
import subprocess
import sys

import pytest


_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.data.graphs import make_graph
from repro.models.gnn import (SAGEConfig, init_sage, sage_forward,
                              sage_forward_sharded)
from repro.distributed.sharding import ShardCtx

N, E, D, C = 512, 2048, 24, 6
g = make_graph(N, E, D, C, seed=3)
cfg = SAGEConfig(n_layers=2, d_in=D, d_hidden=32, n_classes=C)
params = init_sage(jax.random.key(0), cfg)

ref = sage_forward(params, jnp.asarray(g.feats), jnp.asarray(g.edges), cfg)

# Host-side prep for the sharded layout: 4 data shards.
mesh = jax.make_mesh((4, 2), ("data", "model"))
ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
n_shards = 4
n_loc = N // n_shards
# Precompute first-hop mean aggregate (weight-independent).
agg0 = np.zeros((N, D), np.float32)
deg = np.zeros(N, np.float32)
np.add.at(agg0, g.edges[:, 1], g.feats[g.edges[:, 0]])
np.add.at(deg, g.edges[:, 1], 1.0)
agg0 /= np.maximum(deg, 1.0)[:, None]
# Bin edges by dst owner, pad bins to equal width.
owner = g.edges[:, 1] // n_loc
bins = [g.edges[owner == s] for s in range(n_shards)]
w = max(len(b) for b in bins)
edges_sh = np.full((n_shards * w, 2), -1, np.int32)
for s, b in enumerate(bins):
    edges_sh[s * w : s * w + len(b)] = b

got = sage_forward_sharded(
    params, jnp.asarray(g.feats), jnp.asarray(agg0),
    jnp.asarray(edges_sh), cfg, N, ctx,
)
err = float(jnp.max(jnp.abs(got - ref)))
rel = err / (float(jnp.max(jnp.abs(ref))) + 1e-9)
# bf16 hidden gather in the sharded path -> loose-ish tolerance.
assert rel < 3e-2, rel
print("GNN_SHARDED_OK", rel)
"""


@pytest.mark.slow
def test_sharded_sage_matches_baseline():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT, timeout=900,
    )
    assert "GNN_SHARDED_OK" in out.stdout, out.stdout[-1500:] + out.stderr[-2500:]
