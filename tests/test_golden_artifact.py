"""Golden-artifact compatibility: a pinned v1 artifact must load forever.

``tests/golden/tiny_v1`` is a format-v1 artifact (raw int32 docids, int8
impacts, no frozen collection stats) committed before the format-v2 bump.
It pins three guarantees:

  * old artifacts keep loading bitwise under ``SUPPORTED_FORMAT_VERSIONS``
    (same fingerprint, same arrays as a from-scratch rebuild of the same
    corpus) — a format bump must never strand deployed indexes;
  * pre-incremental artifacts keep *refusing* extension, with the same
    error, because they carry no frozen stats;
  * ``repack`` migrates the v1 artifact to packed v2 with arrays
    byte-identical to saving the rebuilt index packed from scratch.

Regenerating the golden (only if the index build itself legitimately
changes) invalidates the pinned fingerprint below on purpose.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.clustered_index import build_index, extend_index
from repro.data.synth import make_corpus
from repro.index_io import (
    FORMAT_VERSION,
    VersionMismatchError,
    load_index,
    read_manifest,
    repack,
    save_index,
    validate_artifact,
)
from repro.index_io.__main__ import main as cli_main

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "tiny_v1")
GOLDEN_FINGERPRINT = "d731d1fda1b4a01a"


def _golden_corpus():
    return make_corpus(
        n_docs=80, n_terms=60, n_topics=3, mean_doc_len=20, seed=123
    )


def _golden_index():
    idx = build_index(
        _golden_corpus(), n_ranges=3, strategy="clustered", bits=8, seed=0
    )
    return dataclasses.replace(idx, stats=None)  # golden predates stats


def test_golden_v1_loads_bitwise():
    manifest = read_manifest(GOLDEN)
    assert manifest["format_version"] == 1 < FORMAT_VERSION
    assert manifest["fingerprint"] == GOLDEN_FINGERPRINT
    assert validate_artifact(GOLDEN) == []

    loaded = load_index(GOLDEN)
    assert loaded.fingerprint() == GOLDEN_FINGERPRINT
    assert loaded.stats is None
    rebuilt = _golden_index()
    assert rebuilt.fingerprint() == GOLDEN_FINGERPRINT
    np.testing.assert_array_equal(loaded.docs, rebuilt.docs)
    np.testing.assert_array_equal(loaded.impacts, rebuilt.impacts)
    np.testing.assert_array_equal(loaded.blk_start, rebuilt.blk_start)
    np.testing.assert_array_equal(loaded.blk_len, rebuilt.blk_len)
    np.testing.assert_array_equal(loaded.bounds_dense, rebuilt.bounds_dense)


def test_golden_v1_still_refuses_extension():
    """Stats-less pre-incremental artifacts refuse append, as always."""
    loaded = load_index(GOLDEN)
    delta = make_corpus(
        n_docs=10, n_terms=60, n_topics=3, mean_doc_len=20, seed=321
    )
    with pytest.raises(ValueError, match="no frozen collection stats"):
        extend_index(loaded, delta)


def test_unknown_format_version_refused(tmp_path):
    """The version gate rejects futures explicitly, not with a KeyError."""
    out = tmp_path / "future"
    save_index(_golden_index(), str(out))
    mpath = out / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = FORMAT_VERSION + 1
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(VersionMismatchError):
        read_manifest(str(out))


def test_repack_golden_equals_from_scratch_packed(tmp_path):
    """v1 -> packed-v2 migration is bitwise the from-scratch packed save."""
    repacked = str(tmp_path / "repacked")
    scratch = str(tmp_path / "scratch")
    assert cli_main(["repack", GOLDEN, "--out", repacked]) == 0
    save_index(
        _golden_index(), scratch, impact_dtype="int8", docs_format="packed"
    )

    mr = read_manifest(repacked)
    ms = read_manifest(scratch)
    assert mr["format_version"] == FORMAT_VERSION
    assert mr["docs_format"] == "packed" and "docs" not in mr["arrays"]
    assert mr["fingerprint"] == GOLDEN_FINGERPRINT
    assert mr["arrays"].keys() == ms["arrays"].keys()
    for name in mr["arrays"]:
        assert mr["arrays"][name]["sha256"] == ms["arrays"][name]["sha256"], name
    assert mr["build_params"]["repacked_from"] == os.path.abspath(GOLDEN)

    assert validate_artifact(repacked) == []
    round_tripped = load_index(repacked)
    assert round_tripped.fingerprint() == GOLDEN_FINGERPRINT
    np.testing.assert_array_equal(round_tripped.docs, load_index(GOLDEN).docs)
