"""Incremental index artifacts (DESIGN.md §10): delta segments, manifest
chains, compaction, and the replayable topology journal.

The tier-1 invariant under test is bitwise: appending ×N then compacting
must equal a from-scratch build on the concatenated corpus at the base's
arrangement-extension, shared quantizer, and *frozen* collection
statistics — array for array, at either impact storage dtype, eager or
memory-mapped. Journal replay must reconstruct cuts + ledger state across
a process boundary with bitwise-identical serving.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import index_io
from repro.control import ControlPlane, TopologyJournal
from repro.core.clustered_index import (
    apply_delta,
    build_index,
    extend_index,
    plan_delta,
)
from repro.core.range_daat import Engine
from repro.data.synth import concat_corpora, make_corpus, make_query_log
from repro.serving import BucketSpec

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

INDEX_FIELDS = (
    "ptr", "docs", "impacts",
    "blk_start", "blk_len", "blk_maxdoc", "blk_maximp", "blk_term", "blk_range",
    "tr_ptr", "tr_range", "tr_blk_start", "tr_blk_end", "tr_bound",
    "term_bound", "bounds_dense",
)


@pytest.fixture(scope="module")
def base_corpus():
    return make_corpus(n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=7)


@pytest.fixture(scope="module")
def deltas():
    return [
        make_corpus(n_docs=150, n_terms=700, n_topics=4, mean_doc_len=50, seed=21),
        make_corpus(n_docs=90, n_terms=700, n_topics=4, mean_doc_len=50, seed=22),
        make_corpus(n_docs=60, n_terms=700, n_topics=4, mean_doc_len=50, seed=23),
    ]


@pytest.fixture(scope="module")
def base_index(base_corpus):
    return build_index(base_corpus, n_ranges=6, strategy="clustered")


def _assert_index_equal(a, b):
    for f in INDEX_FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert x.dtype == y.dtype, f
        np.testing.assert_array_equal(x, y, err_msg=f)
    np.testing.assert_array_equal(
        a.arrangement.doc_order, b.arrangement.doc_order
    )
    np.testing.assert_array_equal(a.range_ends, b.range_ends)
    assert (a.n_docs, a.n_terms) == (b.n_docs, b.n_terms)
    assert a.fingerprint() == b.fingerprint()


# --------------------------------------------------------------------------
# Core: extend_index == fresh build, bitwise
# --------------------------------------------------------------------------


def test_extend_index_matches_fresh_build_bitwise(base_corpus, base_index, deltas):
    """Append x2 in memory == one from-scratch build on the concatenated
    corpus at the extended arrangement / shared quantizer / frozen stats."""
    ext1 = extend_index(base_index, deltas[0], n_ranges=2, seed=5)
    ext2 = extend_index(ext1, deltas[1], n_ranges=1, seed=6)
    assert ext2.n_docs == base_index.n_docs + 240
    assert ext2.n_ranges == base_index.n_ranges + 3
    # Frozen stats travel untouched through the chain.
    assert ext2.stats is base_index.stats

    cat = concat_corpora(concat_corpora(base_corpus, deltas[0]), deltas[1])
    fresh = build_index(
        cat,
        arrangement=ext2.arrangement,
        quantizer=base_index.quantizer,
        stats=base_index.stats,
        params=base_index.bm25,
    )
    _assert_index_equal(ext2, fresh)


def test_extended_index_serves_and_finds_new_docs(base_index, deltas):
    """Document-ordered invariants hold: the extended engine serves, and
    appended docs (docids >= old n_docs) are retrievable."""
    ext = extend_index(base_index, deltas[0], n_ranges=2, seed=5)
    eng = Engine(ext, k=10)
    log = make_query_log(deltas[0], n_queries=8, seed=30)
    hit_new = 0
    for i in range(log.n_queries):
        res = eng.traverse(eng.plan(log.terms[i]))
        ids = np.asarray(res.state.ids)
        ids = ids[ids >= 0]
        assert ids.size > 0
        hit_new += int((ids >= base_index.n_docs).sum())
    assert hit_new > 0  # delta-topic queries surface delta documents


def test_extend_validations(base_corpus, base_index, deltas):
    import dataclasses

    with pytest.raises(ValueError, match="vocabulary|terms"):
        extend_index(
            base_index,
            make_corpus(n_docs=50, n_terms=300, n_topics=2, seed=1),
        )
    empty = dataclasses.replace(
        deltas[0], n_docs=0, doc_ptr=np.zeros(1, np.int64),
        doc_terms=np.empty(0, np.int32), doc_tfs=np.empty(0, np.int32),
        doc_topic=np.empty(0, np.int32),
    )
    with pytest.raises(ValueError, match="empty"):
        extend_index(base_index, empty)
    # Pre-§10 index (no frozen stats) cannot be extended.
    statless = dataclasses.replace(base_index, stats=None)
    with pytest.raises(ValueError, match="stats"):
        extend_index(statless, deltas[0])
    # A delta planned against another index is refused at apply time.
    other = build_index(base_corpus, n_ranges=4, strategy="clustered", seed=9)
    delta = plan_delta(other, deltas[0])
    with pytest.raises(ValueError, match="planned against"):
        apply_delta(base_index, delta)


# --------------------------------------------------------------------------
# Artifacts: chain round-trip, compaction, crash recovery
# --------------------------------------------------------------------------


@pytest.mark.parametrize("impact_dtype", ["int32", "int8"])
@pytest.mark.parametrize("mmap", [False, True])
def test_chain_roundtrip_and_compact_bitwise(
    base_corpus, base_index, deltas, tmp_path, impact_dtype, mmap
):
    """append xN -> load_chain == compact == fresh build, bitwise."""
    base = str(tmp_path / "base")
    index_io.save_index(base_index, base, impact_dtype=impact_dtype)
    parent, cat = base, base_corpus
    for i, d in enumerate(deltas):
        head = str(tmp_path / f"delta{i}")
        ext = index_io.append_index(parent, d, head, n_ranges=1 + i % 2, seed=40 + i)
        cat = concat_corpora(cat, d)
        parent = head

    manifest = index_io.read_manifest(parent)
    assert manifest["chain_length"] == len(deltas)
    assert manifest["impact_dtype"] == impact_dtype
    assert manifest["n_docs_total"] == cat.n_docs

    loaded = index_io.load_index(parent, mmap=mmap)
    assert loaded.fingerprint() == ext.fingerprint() == manifest["fingerprint"]

    out = str(tmp_path / "compacted")
    index_io.compact(parent, out)
    assert index_io.read_manifest(out)["impact_dtype"] == impact_dtype
    compacted = index_io.load_index(out, mmap=mmap)

    fresh = build_index(
        cat,
        arrangement=ext.arrangement,
        quantizer=base_index.quantizer,
        stats=base_index.stats,
        params=base_index.bm25,
    )
    _assert_index_equal(loaded, fresh)
    _assert_index_equal(compacted, fresh)
    # Frozen stats round-trip through the chain and the compacted base.
    for idx in (loaded, compacted):
        assert idx.stats is not None
        assert idx.stats.n_docs == base_index.stats.n_docs
        assert idx.stats.avg_doc_len == base_index.stats.avg_doc_len
        np.testing.assert_array_equal(idx.stats.df, base_index.stats.df)
    assert index_io.validate_artifact(parent) == []
    assert index_io.validate_artifact(out) == []


def test_engine_from_chain_head_serves_bitwise(base_index, deltas, tmp_path):
    base = str(tmp_path / "base")
    head = str(tmp_path / "head")
    index_io.save_index(base_index, base, impact_dtype="int8")
    ext = index_io.append_index(base, deltas[0], head, n_ranges=2, seed=5)

    eng = Engine.from_artifact(head, k=10)
    assert eng.impact_dtype == "int8"  # inherits the chain head's dtype
    ref = Engine(ext, k=10)
    log = make_query_log(deltas[0], n_queries=6, seed=31)
    for i in range(log.n_queries):
        a = eng.traverse(eng.plan(log.terms[i]))
        b = ref.traverse(ref.plan(log.terms[i]))
        assert np.asarray(a.state.ids).tolist() == np.asarray(b.state.ids).tolist()
        assert np.asarray(a.state.vals).tolist() == np.asarray(b.state.vals).tolist()


def test_crash_mid_append_staging_ignored_and_cleaned(
    base_index, deltas, tmp_path
):
    """A crashed append's partial staging dir neither corrupts loads nor
    survives the sweep; a *fresh* staging dir is left alone."""
    base = str(tmp_path / "base")
    head = str(tmp_path / "head")
    index_io.save_index(base_index, base)
    index_io.append_index(base, deltas[0], head)

    stale = str(tmp_path / "head.tmp-CRASHED")
    os.makedirs(os.path.join(stale, "arrays"))
    with open(os.path.join(stale, "arrays", "docs.npy"), "w") as f:
        f.write("partial garbage")
    # Readers never look at staging dirs: the chain stays healthy.
    assert index_io.load_index(head).n_docs == base_index.n_docs + deltas[0].n_docs
    assert index_io.validate_artifact(head) == []

    removed = index_io.clean_stale_staging(head, max_age_s=0.0)
    assert "head.tmp-CRASHED" in removed
    assert not os.path.exists(stale)
    # Default window protects a concurrent save's live staging area.
    fresh = str(tmp_path / "head.tmp-LIVE")
    os.makedirs(fresh)
    assert index_io.clean_stale_staging(head) == []
    assert os.path.isdir(fresh)

    # A re-run append on the same target publishes cleanly over the crash.
    index_io.append_index(base, deltas[0], head, overwrite=True)
    assert index_io.validate_artifact(head) == []


def test_mis_chained_and_corrupt_deltas_refused(
    base_corpus, base_index, deltas, tmp_path
):
    base = str(tmp_path / "base")
    other = str(tmp_path / "other")
    index_io.save_index(base_index, base)
    other_index = build_index(base_corpus, n_ranges=4, strategy="clustered", seed=9)
    index_io.save_index(other_index, other)

    # save_delta refuses a parent whose fingerprint is not the delta's.
    delta = plan_delta(base_index, deltas[0])
    with pytest.raises(index_io.ArtifactError, match="planned against"):
        index_io.save_delta(delta, str(tmp_path / "d"), other, "whatever")

    head = str(tmp_path / "head")
    index_io.append_index(base, deltas[0], head)

    # Broken parent pointer -> CorruptArtifactError (load + validate).
    mpath = os.path.join(head, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    good_parent = manifest["parent"]
    manifest["parent"] = "../nowhere"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(index_io.CorruptArtifactError):
        index_io.load_index(head)
    assert index_io.validate_artifact(head) != []

    # Tampered result fingerprint -> materialization mismatch raises.
    manifest["parent"] = good_parent
    manifest["fingerprint"] = "0" * 16
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(index_io.CorruptArtifactError, match="fingerprint"):
        index_io.load_index(head)


def test_pre_incremental_artifact_cannot_extend(base_index, deltas, tmp_path):
    """An artifact saved before §10 (no collection stats) loads fine but
    refuses extension with a clear error; a HALF-present stats record is
    corruption and fails at load time instead."""
    base = str(tmp_path / "base")
    index_io.save_index(base_index, base)
    mpath = os.path.join(base, "manifest.json")
    with open(mpath) as f:
        saved = json.load(f)

    # Exactly one of (manifest collection, stats_df array) present: corrupt.
    for drop in ("collection", "stats_df"):
        manifest = json.loads(json.dumps(saved))
        if drop == "collection":
            del manifest["collection"]
        else:
            del manifest["arrays"]["stats_df"]
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(index_io.CorruptArtifactError, match="stats"):
            index_io.load_index(base)

    # Both absent: a legitimate pre-§10 artifact — loads, refuses extension.
    manifest = json.loads(json.dumps(saved))
    del manifest["collection"]
    del manifest["arrays"]["stats_df"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    loaded = index_io.load_index(base)
    assert loaded.stats is None
    assert loaded.fingerprint() == base_index.fingerprint()
    with pytest.raises(ValueError, match="stats"):
        index_io.append_index(base, deltas[0], str(tmp_path / "d"))


# --------------------------------------------------------------------------
# Topology journal
# --------------------------------------------------------------------------


def test_topology_journal_records_and_torn_tail(tmp_path):
    j = TopologyJournal(str(tmp_path / "journal.jsonl"))
    assert j.records() == [] and not j.exists
    j.append({"kind": "health", "event": "down", "shard": 1, "replica": None})
    j.append({"kind": "reshard", "cuts": [0, 2, 6]})
    recs = j.records()
    assert [r["seq"] for r in recs] == [0, 1]
    assert recs[1]["cuts"] == [0, 2, 6]
    # Torn final line (crash mid-append) is ignored...
    with open(j.path, "a") as f:
        f.write('{"kind": "resha')
    assert len(j.records()) == 2
    assert j.next_seq() == 2
    # ...but a corrupt line in the *middle* is a hard error.
    with open(j.path, "a") as f:
        f.write('rd"\n{"kind": "health", "event": "up", "shard": 1}\n')
    with pytest.raises(ValueError, match="corrupt"):
        j.records()


def test_journal_append_after_torn_tail_truncates_not_concatenates(tmp_path):
    """Bug regression: appending after a crash-torn tail must truncate the
    uncommitted fragment first — naive 'a'-mode writes would merge the new
    record into the torn line, silently losing it (or corrupting the
    journal for every later read)."""
    j = TopologyJournal(str(tmp_path / "journal.jsonl"))
    j.append({"kind": "health", "event": "down", "shard": 0, "replica": None})
    with open(j.path, "a") as f:
        f.write('{"kind": "resha')  # crash mid-append, no newline
    # A restarted writer (fresh object, like a fresh process) appends twice.
    j2 = TopologyJournal(j.path)
    j2.append({"kind": "reshard", "cuts": [0, 2, 4]})
    j2.append({"kind": "health", "event": "up", "shard": 0, "replica": None})
    recs = j2.records()
    assert [r["kind"] for r in recs] == ["health", "reshard", "health"]
    assert [r["seq"] for r in recs] == [0, 1, 2]
    assert recs[1]["cuts"] == [0, 2, 4]


def test_plane_journal_replay_reconstructs_cuts_and_ledger(
    base_index, tmp_path
):
    """The §10 acceptance, in-process: a second plane opened on the same
    artifact with replay=True resumes at the journaled layout + ledger and
    serves bitwise-identically."""
    path = str(tmp_path / "art")
    index_io.save_index(base_index, path)
    kw = dict(
        n_shards=3, use_mesh=False, spec=BucketSpec(max_batch=4),
        engine_kwargs=dict(k=5),
    )
    plane = ControlPlane.from_artifact(path, **kw)
    assert plane.journal is not None and not plane.journal.exists

    plane.start_reshard(np.asarray([0, 1, 4, 6]))
    while plane.reshard_task is not None:
        plane.drain_once()
    plane.mark_down(1)
    plane.mark_up(1)
    plane.mark_down(2)
    assert len(plane.journal.records()) == 4

    # "Process restart": a fresh plane over the same artifact.
    plane2 = ControlPlane.from_artifact(path, replay=True, **kw)
    np.testing.assert_array_equal(plane2.cuts, plane.cuts)
    np.testing.assert_array_equal(plane2.health._up, plane.health._up)
    assert plane2.reshards_completed == 1
    # Replay is idempotent: nothing was re-journaled.
    assert len(plane.journal.records()) == 4

    log = make_query_log(
        make_corpus(n_docs=200, n_terms=700, n_topics=4, seed=2), n_queries=6,
        seed=3,
    )
    for i in range(log.n_queries):
        a = plane.bengine.run_batch([plane.engine.plan(log.terms[i])])[0]
        b = plane2.bengine.run_batch([plane2.engine.plan(log.terms[i])])[0]
        assert a.doc_ids.tolist() == b.doc_ids.tolist()
        assert a.scores.tolist() == b.scores.tolist()
        assert a.shard_exit_reasons == b.shard_exit_reasons

    # Dying mid-reshard: an *uncommitted* cutover leaves no record, so a
    # restart resumes at the last committed layout.
    plane.mark_up(2)
    plane.start_reshard(np.asarray([0, 2, 4, 6]))  # never drained to cutover
    plane3 = ControlPlane.from_artifact(path, replay=True, **kw)
    np.testing.assert_array_equal(plane3.cuts, [0, 1, 4, 6])
    assert plane3.health.all_up


def test_replay_skips_health_records_from_before_last_reshard(
    base_index, tmp_path
):
    """Health records journaled before a committed reshard reference the
    OLD layout's shard ids (the live cutover reset the ledger); replay
    must skip them — including ids the new, smaller layout doesn't have —
    and still count every committed reshard."""
    path = str(tmp_path / "art")
    index_io.save_index(base_index, path)
    kw = dict(
        use_mesh=False, spec=BucketSpec(max_batch=4), engine_kwargs=dict(k=5)
    )
    plane = ControlPlane.from_artifact(path, n_shards=4, **kw)
    plane.mark_down(3)  # only valid under the 4-shard layout
    plane.mark_up(3)
    plane.start_reshard(np.asarray([0, 1, base_index.n_ranges]))  # 4 -> 2
    while plane.reshard_task is not None:
        plane.drain_once()
    plane.mark_down(1)  # post-reshard: names a 2-shard-layout shard

    plane2 = ControlPlane.from_artifact(path, n_shards=2, replay=True, **kw)
    np.testing.assert_array_equal(plane2.cuts, [0, 1, base_index.n_ranges])
    assert plane2.reshards_completed == 1
    assert plane2.health.shard_down_mask().tolist() == [False, True]


def test_plane_refuses_foreign_journal(base_corpus, base_index, tmp_path):
    """Records stamped with another index's fingerprint must not replay."""
    path = str(tmp_path / "art")
    index_io.save_index(base_index, path)
    plane = ControlPlane.from_artifact(
        path, n_shards=2, use_mesh=False, engine_kwargs=dict(k=5)
    )
    plane.mark_down(0)

    other = build_index(base_corpus, n_ranges=4, strategy="clustered", seed=9)
    opath = str(tmp_path / "other")
    index_io.save_index(other, opath)
    # Copy the journal under the other artifact to simulate a mixed-up tree.
    import shutil

    shutil.copy(
        os.path.join(path, "journal.jsonl"), os.path.join(opath, "journal.jsonl")
    )
    with pytest.raises(index_io.ArtifactError, match="foreign"):
        ControlPlane.from_artifact(
            opath, n_shards=2, replay=True, use_mesh=False,
            engine_kwargs=dict(k=5),
        )


def test_plane_from_chain_head_with_journal(base_index, deltas, tmp_path):
    """The journal lives with the chain head it describes: opening the head
    journals against the *materialized* fingerprint."""
    base = str(tmp_path / "base")
    head = str(tmp_path / "head")
    index_io.save_index(base_index, base)
    ext = index_io.append_index(base, deltas[0], head, n_ranges=2, seed=5)
    plane = ControlPlane.from_artifact(
        head, n_shards=3, use_mesh=False, engine_kwargs=dict(k=5)
    )
    assert plane.engine.index.fingerprint() == ext.fingerprint()
    plane.mark_down(2)
    assert plane.journal.records()[0]["fingerprint"] == ext.fingerprint()
    plane2 = ControlPlane.from_artifact(
        head, n_shards=3, replay=True, use_mesh=False, engine_kwargs=dict(k=5)
    )
    assert plane2.health.shard_down_mask().tolist() == [False, False, True]


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def test_cli_append_compact_log(tmp_path, capsys):
    from repro.index_io.__main__ import main as cli

    base = str(tmp_path / "idx")
    head = str(tmp_path / "idx.d1")
    assert cli([
        "build", "--out", base, "--reader", "synth",
        "--n-docs", "400", "--n-terms", "300", "--n-topics", "4",
        "--n-ranges", "4", "--impact-dtype", "int8",
    ]) == 0
    assert cli([
        "append", "--parent", base, "--out", head, "--reader", "synth",
        "--n-docs", "80", "--n-terms", "300", "--n-topics", "4",
        "--seed", "11",
    ]) == 0
    out = capsys.readouterr().out
    assert "chain length 1" in out
    assert cli(["log", head]) == 0
    out = capsys.readouterr().out
    assert "clustered_index base" in out and "delta +80 docs" in out
    assert cli(["validate", head]) == 0
    assert cli(["inspect", head]) == 0
    compacted = str(tmp_path / "idx.compact")
    assert cli(["compact", head, "--out", compacted]) == 0
    assert cli(["validate", compacted]) == 0
    # Compacted base serves the same index as the chain head.
    assert (
        index_io.load_index(compacted).fingerprint()
        == index_io.read_manifest(head)["fingerprint"]
    )
    # Appending against a missing parent is a clean exit-1, not a traceback.
    assert cli([
        "append", "--parent", str(tmp_path / "nope"), "--out",
        str(tmp_path / "x"), "--n-docs", "10", "--n-terms", "300",
    ]) == 1


# --------------------------------------------------------------------------
# Journal replay across a real process boundary, forced 4-device CPU mesh
# --------------------------------------------------------------------------

_JOURNAL_SUBPROC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro import index_io
from repro.control import ControlPlane
from repro.core.clustered_index import build_index
from repro.data.synth import make_corpus, make_query_log
from repro.serving import BucketSpec

assert jax.device_count() == 4
path, phase = sys.argv[1], sys.argv[2]
corpus = make_corpus(n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=7)
log = make_query_log(corpus, n_queries=8, seed=8)
queries = [log.terms[i] for i in range(log.n_queries)]
kw = dict(n_shards=4, spec=BucketSpec(max_batch=4), engine_kwargs=dict(k=5))

if phase == "write":
    idx = build_index(corpus, n_ranges=8, strategy="clustered")
    index_io.save_index(idx, path)
    plane = ControlPlane.from_artifact(path, **kw)
    assert plane.sengine.mesh is not None  # 4 shards on 4 devices
    plane.start_reshard(np.asarray([0, 1, 3, 6, 8]))
    while plane.reshard_task is not None:
        plane.submit(queries[0]); plane.drain_once()
    plane.mark_down(3)
    served = plane.replay(queries, batch_size=4)
    rows = [[s.result.doc_ids.tolist(), s.result.scores.tolist(),
             list(s.result.shard_exit_reasons), s.result.fidelity_bound,
             bool(s.result.exact)] for s in sorted(served, key=lambda s: s.rid)]
    import json
    with open(path + ".expect.json", "w") as f:
        json.dump({"cuts": plane.cuts.tolist(),
                   "up": plane.health._up.tolist(), "rows": rows}, f)
    print("WRITE_OK", len(plane.journal.records()))
else:
    import json
    with open(path + ".expect.json") as f:
        expect = json.load(f)
    plane = ControlPlane.from_artifact(path, replay=True, **kw)
    assert plane.cuts.tolist() == expect["cuts"], plane.cuts
    assert plane.health._up.tolist() == expect["up"]
    served = plane.replay(queries, batch_size=4)
    rows = [[s.result.doc_ids.tolist(), s.result.scores.tolist(),
             list(s.result.shard_exit_reasons), s.result.fidelity_bound,
             bool(s.result.exact)] for s in sorted(served, key=lambda s: s.rid)]
    assert rows == expect["rows"]
    print("REPLAY_OK", len(queries))
"""


@pytest.mark.slow
def test_journal_replay_across_process_boundary_subprocess(tmp_path):
    """Tentpole acceptance: a broker process dies (here: exits) after a
    journaled reshard + outage; a NEW process replays the journal and
    serves the degraded layout bitwise-identically on a forced 4-device
    CPU mesh."""
    path = str(tmp_path / "art")
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}
    for phase, marker in (("write", "WRITE_OK"), ("replay", "REPLAY_OK")):
        out = subprocess.run(
            [sys.executable, "-c", _JOURNAL_SUBPROC, path, phase],
            capture_output=True, text=True, env=env, cwd=_REPO_ROOT,
            timeout=900,
        )
        assert marker in out.stdout, out.stdout + out.stderr
