"""Index lifecycle subsystem (DESIGN.md §8): artifacts, ingestion, int8.

Parity contracts are bitwise, matching the repo-wide convention: a loaded
artifact must produce `device_traverse` results identical to the
in-memory build — docids, scores, and tie-breaks — at either impact
storage dtype, single-device and sharded.
"""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro import index_io
from repro.core.clustered_index import build_index_cached, shard_device_index
from repro.core.range_daat import IMPACT_BIAS, Engine, pack_impacts
from repro.index_io import corpus_io
from repro.index_io.__main__ import main as index_io_cli
from repro.serving.sharded import ShardedEngine

DTYPES = ("int32", "int8")
SHARD_FIELDS = (
    "docs", "impacts", "blk_start", "blk_len", "blk_maxdoc", "blk_maximp",
    "blk_map", "range_starts", "range_sizes", "bounds_dense",
)


def _topk(engine, q):
    res = engine.traverse(engine.plan(q))
    return (
        np.asarray(res.state.ids).tolist(),
        np.asarray(res.state.vals).tolist(),
    )


# --------------------------------------------------------------------------
# Artifact round-trip — single device
# --------------------------------------------------------------------------


@pytest.mark.parametrize("impact_dtype", DTYPES)
@pytest.mark.parametrize("mmap", [False, True])
def test_round_trip_bitwise(index, queries, tmp_path, impact_dtype, mmap):
    path = str(tmp_path / f"art_{impact_dtype}")
    index_io.save_index(index, path, impact_dtype=impact_dtype)
    loaded = index_io.load_index(path, mmap=mmap)

    # Fingerprint stability across save/load (impacts widen back to exact
    # int32, so the int8 artifact hashes identically).
    assert loaded.fingerprint() == index.fingerprint()
    assert index_io.read_manifest(path)["fingerprint"] == index.fingerprint()

    ref = Engine(index, k=10)
    eng = Engine(loaded, k=10, impact_dtype=impact_dtype)
    for q in queries[:6]:
        assert _topk(eng, q) == _topk(ref, q)


def test_int8_engine_matches_int32_results(index, queries):
    """Native int8 HBM storage must not change any retrieval result."""
    e32 = Engine(index, k=10)
    e8 = Engine(index, k=10, impact_dtype="int8")
    for q in queries:
        assert _topk(e8, q) == _topk(e32, q)
    # Budgeted (anytime) traversals take the same early exits too.
    for q in queries[:4]:
        r32 = e32.traverse(e32.plan(q), budget_postings=512)
        r8 = e8.traverse(e8.plan(q), budget_postings=512)
        assert np.array_equal(np.asarray(r32.state.ids), np.asarray(r8.state.ids))
        assert np.array_equal(np.asarray(r32.state.vals), np.asarray(r8.state.vals))
        assert bool(r32.exit_budget) == bool(r8.exit_budget)


def test_pack_impacts_bias_roundtrip(index):
    packed = pack_impacts(index.impacts, "int8")
    assert packed.dtype == np.int8
    assert np.array_equal(
        packed.astype(np.int64) + IMPACT_BIAS, index.impacts.astype(np.int64)
    )
    with pytest.raises(ValueError):
        pack_impacts(index.impacts, "int16")


def test_int8_rejected_above_8_bits(corpus, clustered_arrangement, tmp_path):
    from repro.core.clustered_index import build_index

    idx9 = build_index(corpus, arrangement=clustered_arrangement, bits=9)
    with pytest.raises(ValueError, match="bits <= 8"):
        Engine(idx9, impact_dtype="int8")
    # Disk path rejects too, and a failed save leaves no staging dir behind.
    with pytest.raises(ValueError, match="bits <= 8"):
        index_io.save_index(idx9, str(tmp_path / "idx9"), impact_dtype="int8")
    assert [d for d in os.listdir(tmp_path) if ".tmp-" in d] == []


# --------------------------------------------------------------------------
# Artifact round-trip — shards
# --------------------------------------------------------------------------


@pytest.mark.parametrize("impact_dtype", DTYPES)
def test_shards_round_trip(index, queries, tmp_path, impact_dtype):
    shards = shard_device_index(index, 2)
    path = str(tmp_path / "shards")
    index_io.save_shards(
        shards, path, impact_dtype=impact_dtype, quantizer=index.quantizer
    )
    loaded = index_io.load_shards(path)

    assert len(loaded) == len(shards)
    for a, b in zip(shards, loaded):
        for f in SHARD_FIELDS:
            assert np.array_equal(getattr(a, f), getattr(b, f)), f
        assert (a.shard_id, a.range_lo, a.range_hi, a.doc_base, a.n_docs,
                a.postings) == (b.shard_id, b.range_lo, b.range_hi,
                                b.doc_base, b.n_docs, b.postings)

    # 2-shard device parity: loaded shards drive the same merged top-k.
    ref = ShardedEngine(Engine(index, k=10), 2, use_mesh=False)
    eng = ShardedEngine(
        Engine(index, k=10, impact_dtype=impact_dtype), 2,
        use_mesh=False, shards=loaded,
    )
    for q in queries[:6]:
        r0 = ref.traverse(ref.plan(q))
        r1 = eng.traverse(eng.plan(q))
        assert r0.doc_ids.tolist() == r1.doc_ids.tolist()
        assert r0.scores.tolist() == r1.scores.tolist()


def test_shards_preloaded_count_checked(index):
    shards = shard_device_index(index, 2)
    with pytest.raises(ValueError, match="shard count"):
        ShardedEngine(Engine(index, k=10), 3, use_mesh=False, shards=shards)


def test_shards_int8_requires_quantizer(index, tmp_path):
    shards = shard_device_index(index, 2)
    with pytest.raises(ValueError, match="quantizer"):
        index_io.save_shards(shards, str(tmp_path / "s"), impact_dtype="int8")


def test_from_artifact_end_to_end(index, queries, tmp_path):
    """The full loading surface: index artifact + shard artifact + engines."""
    path = str(tmp_path / "idx")
    spath = str(tmp_path / "idx.shards2")
    index_io.save_index(index, path, impact_dtype="int8")
    index_io.save_shards(
        shard_device_index(index, 2), spath, impact_dtype="int8",
        quantizer=index.quantizer, source_fingerprint=index.fingerprint(),
    )

    eng = Engine.from_artifact(path, k=10)
    assert eng.impact_dtype == "int8"  # defaults to the artifact's dtype
    seng = ShardedEngine.from_artifact(
        path, 2, shards_path=spath, use_mesh=False, k=10
    )
    ref = ShardedEngine(Engine(index, k=10), 2, use_mesh=False)
    for q in queries[:3]:
        r0 = ref.traverse(ref.plan(q))
        r1 = seng.traverse(seng.plan(q))
        assert r0.doc_ids.tolist() == r1.doc_ids.tolist()
        assert r0.scores.tolist() == r1.scores.tolist()


def test_from_artifact_rejects_stale_shards(index, tmp_path):
    """A shard set carved from a different index must not silently serve."""
    from repro.core.clustered_index import build_index
    from repro.data.synth import make_corpus

    other = build_index(
        make_corpus(n_docs=400, n_terms=300, n_topics=4, seed=9), n_ranges=4,
        strategy="clustered",
    )
    opath = str(tmp_path / "other")
    index_io.save_index(other, opath)
    spath = str(tmp_path / "stale.shards")
    index_io.save_shards(
        shard_device_index(index, 2), spath,
        quantizer=index.quantizer, source_fingerprint=index.fingerprint(),
    )
    with pytest.raises(index_io.ArtifactError, match="carved from"):
        ShardedEngine.from_artifact(opath, 2, shards_path=spath, use_mesh=False)

    # A shard set with no recorded source fingerprint is unverifiable and
    # equally refused (load_shards + ShardedEngine(shards=...) bypasses).
    upath = str(tmp_path / "unverifiable.shards")
    index_io.save_shards(shard_device_index(index, 2), upath,
                         quantizer=index.quantizer)
    with pytest.raises(index_io.ArtifactError, match="source_fingerprint"):
        ShardedEngine.from_artifact(opath, 2, shards_path=upath, use_mesh=False)


# --------------------------------------------------------------------------
# device_bytes accounting
# --------------------------------------------------------------------------


@pytest.mark.parametrize("impact_dtype", DTYPES)
def test_device_bytes_match_uploaded_buffers(index, impact_dtype):
    eng = Engine(index, impact_dtype=impact_dtype)
    dev = index.space_report(impact_dtype)["device_bytes"]
    # pack_* leaves are None in the raw-int32 docid layout and accounted
    # as a single "docs" line in the packed one (tests/test_packed_postings
    # covers that path), so only materialized non-pack leaves line up 1:1.
    fields = [
        n for n in eng.dix._fields
        if not n.startswith("pack_") and getattr(eng.dix, n) is not None
    ]
    for name in fields:
        assert dev[name] == np.asarray(getattr(eng.dix, name)).nbytes, name
    assert dev["postings"] == dev["docs"] + dev["impacts"]
    assert dev["total"] == sum(dev[n] for n in fields)


def test_int8_halves_postings_hbm(index):
    d32 = index.space_report("int32")["device_bytes"]
    d8 = index.space_report("int8")["device_bytes"]
    assert d32["impacts"] == 4 * d8["impacts"]  # 4 B -> 1 B per posting
    assert d32["postings"] / d8["postings"] >= 1.5  # docs stay int32
    assert d8["total"] < d32["total"]
    with pytest.raises(ValueError):
        index.device_bytes("float16")


# --------------------------------------------------------------------------
# Error paths: corruption, versioning, overwrite
# --------------------------------------------------------------------------


@pytest.fixture()
def artifact_path(index, tmp_path):
    path = str(tmp_path / "art")
    index_io.save_index(index, path, impact_dtype="int8")
    return path


def test_corrupt_manifest_raises(artifact_path):
    with open(os.path.join(artifact_path, "manifest.json"), "w") as f:
        f.write("{ not json")
    with pytest.raises(index_io.CorruptArtifactError, match="unparseable"):
        index_io.load_index(artifact_path)
    assert index_io.validate_artifact(artifact_path) != []


def test_version_mismatch_raises(artifact_path):
    mpath = os.path.join(artifact_path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format_version"] = index_io.FORMAT_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(index_io.VersionMismatchError, match="format_version"):
        index_io.load_index(artifact_path)


def test_missing_array_raises(artifact_path):
    os.remove(os.path.join(artifact_path, "arrays", "docs.npy"))
    with pytest.raises(index_io.CorruptArtifactError, match="docs"):
        index_io.load_index(artifact_path)


def test_tampered_array_fails_fingerprint(artifact_path):
    fpath = os.path.join(artifact_path, "arrays", "docs.npy")
    docs = np.load(fpath)
    docs = docs.copy()
    docs[0] += 1
    np.save(fpath, docs)
    with pytest.raises(index_io.CorruptArtifactError, match="fingerprint"):
        index_io.load_index(artifact_path)
    assert any("sha256" in p for p in index_io.validate_artifact(artifact_path))


def test_wrong_kind_raises(index, artifact_path, tmp_path):
    shards = shard_device_index(index, 2)
    spath = str(tmp_path / "shards")
    index_io.save_shards(shards, spath, quantizer=index.quantizer)
    with pytest.raises(index_io.CorruptArtifactError, match="kind"):
        index_io.load_index(spath)
    with pytest.raises(index_io.CorruptArtifactError, match="kind"):
        index_io.load_shards(artifact_path)


def test_concurrent_overwrite_readers_survive_publish_race(index, tmp_path):
    """Bugfix regression: the rename-aside overwrite admits a briefly-absent
    path, so readers (read_manifest / load_index / validate) must retry once
    on ENOENT instead of crashing on a healthy artifact. Stress: one writer
    republishing in a loop against concurrent readers."""
    import threading

    path = str(tmp_path / "live")
    index_io.save_index(index, path)
    stop = threading.Event()
    errors: list = []

    def writer():
        try:
            for _ in range(12):
                index_io.save_index(index, path, overwrite=True)
        except Exception as e:  # noqa: BLE001 — surfaced via `errors`
            errors.append(("writer", repr(e)))
        finally:
            stop.set()

    def reader():
        try:
            while not stop.is_set():
                manifest = index_io.read_manifest(path)
                assert manifest["fingerprint"] == index.fingerprint()
                loaded = index_io.load_index(path, mmap=True)
                assert loaded.fingerprint() == index.fingerprint()
        except Exception as e:  # noqa: BLE001 — surfaced via `errors`
            errors.append(("reader", repr(e)))

    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader) for _ in range(3)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert errors == []
    assert index_io.validate_artifact(path) == []


def test_overwrite_guard(index, artifact_path):
    with pytest.raises(index_io.ArtifactError, match="overwrite"):
        index_io.save_index(index, artifact_path)
    index_io.save_index(index, artifact_path, overwrite=True)  # replaces
    assert index_io.validate_artifact(artifact_path) == []
    # No staging directories left behind (unique per-save `<name>.tmp-*`).
    leftovers = [
        d for d in os.listdir(os.path.dirname(artifact_path)) if ".tmp-" in d
    ]
    assert leftovers == []


# --------------------------------------------------------------------------
# Cached build via the artifact format (pickle path deleted)
# --------------------------------------------------------------------------


def test_build_index_cached_uses_artifacts(tmp_path):
    from repro.data.synth import make_corpus

    c = make_corpus(n_docs=400, n_terms=300, n_topics=4, seed=3)
    cache = str(tmp_path / "cache")
    i1 = build_index_cached(c, cache_dir=cache, n_ranges=4, strategy="clustered")
    entries = os.listdir(cache)
    assert len(entries) == 1 and entries[0].startswith("index_")
    assert not entries[0].endswith(".pkl")  # the pickle path is gone
    assert index_io.validate_artifact(os.path.join(cache, entries[0])) == []
    i2 = build_index_cached(c, cache_dir=cache, n_ranges=4, strategy="clustered")
    assert i2.fingerprint() == i1.fingerprint()
    assert os.listdir(cache) == entries  # cache hit, no rebuild


def test_build_index_cached_self_heals_old_format(tmp_path):
    """A format-version bump is a cache miss, not a permanent crash."""
    from repro.data.synth import make_corpus

    c = make_corpus(n_docs=400, n_terms=300, n_topics=4, seed=3)
    cache = str(tmp_path / "cache")
    i1 = build_index_cached(c, cache_dir=cache, n_ranges=4, strategy="clustered")
    entry = os.path.join(cache, os.listdir(cache)[0])
    mpath = os.path.join(entry, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format_version"] = index_io.FORMAT_VERSION - 1  # "older" format
    with open(mpath, "w") as f:
        json.dump(manifest, f)

    i2 = build_index_cached(c, cache_dir=cache, n_ranges=4, strategy="clustered")
    assert i2.fingerprint() == i1.fingerprint()  # rebuilt, same build inputs
    assert index_io.validate_artifact(entry) == []  # entry rewritten current
    # Corruption still raises (the docstring's contract) — not silently healed.
    with open(mpath, "w") as f:
        f.write("broken")
    with pytest.raises(index_io.CorruptArtifactError):
        build_index_cached(c, cache_dir=cache, n_ranges=4, strategy="clustered")


# --------------------------------------------------------------------------
# Corpus reader registry
# --------------------------------------------------------------------------


def test_tsv_reader_round_trip(tmp_path):
    src = tmp_path / "coll.tsv"
    src.write_text(
        "d0\tthe quick brown fox\n"
        "d1\tquick quick fox jumps\n"
        "\n"
        "d2\tlazy dog sleeps\n"
    )
    c = corpus_io.read_tsv(str(src))
    assert c.n_docs == 3
    # Vocabulary in sorted token order: brown dog fox jumps lazy quick sleeps the
    assert c.n_terms == 8
    t, tf = c.doc_slice(1)
    vocab = {"brown": 0, "dog": 1, "fox": 2, "jumps": 3, "lazy": 4,
             "quick": 5, "sleeps": 6, "the": 7}
    assert dict(zip(t.tolist(), tf.tolist())) == {
        vocab["quick"]: 2, vocab["fox"]: 1, vocab["jumps"]: 1
    }
    c2 = corpus_io.read_corpus("tsv", str(src))
    assert c2.fingerprint() == c.fingerprint()  # deterministic
    assert corpus_io.read_tsv(str(src), max_docs=2).n_docs == 2


def test_jsonl_reader_text_and_terms(tmp_path):
    text_src = tmp_path / "text.jsonl"
    text_src.write_text(
        '{"id": "a", "text": "alpha beta"}\n{"id": "b", "text": "beta gamma"}\n'
    )
    c = corpus_io.read_jsonl(str(text_src))
    assert c.n_docs == 2 and c.n_terms == 3

    term_src = tmp_path / "terms.jsonl"
    term_src.write_text(
        '{"terms": [0, 2], "tfs": [3, 1]}\n{"terms": [1]}\n'
    )
    c = corpus_io.read_jsonl(str(term_src))
    assert c.n_docs == 2 and c.n_terms == 3
    t, tf = c.doc_slice(0)
    assert t.tolist() == [0, 2] and tf.tolist() == [3, 1]

    mixed = tmp_path / "mixed.jsonl"
    mixed.write_text('{"text": "a"}\n{"terms": [0]}\n')
    with pytest.raises(ValueError, match="mixes"):
        corpus_io.read_jsonl(str(mixed))


def test_tsv_reader_rejects_untabbed_line(tmp_path):
    src = tmp_path / "bad.tsv"
    src.write_text("d0\tfine text\nd1 missing tab separator\n")
    with pytest.raises(ValueError, match="no tab"):
        corpus_io.read_tsv(str(src))


def test_ingested_corpus_builds_and_serves(tmp_path):
    """A real-collection reader output drives the full pipeline."""
    lines = []
    rng = np.random.default_rng(0)
    words = [f"w{i}" for i in range(50)]
    for d in range(60):
        toks = rng.choice(words, size=rng.integers(5, 15))
        lines.append(f"doc{d}\t{' '.join(toks)}")
    src = tmp_path / "c.tsv"
    src.write_text("\n".join(lines) + "\n")

    from repro.core.clustered_index import build_index

    c = corpus_io.read_corpus("tsv", str(src))
    idx = build_index(c, n_ranges=2, strategy="clustered")
    eng = Engine(idx, k=5)
    res = eng.traverse(eng.plan(np.asarray([0, 1, 2], np.int32)))
    ids = np.asarray(res.state.ids)
    assert (ids >= 0).any()


def test_gated_readers_clean_without_optional_deps():
    avail = corpus_io.available_readers()
    assert {"synth", "tsv", "jsonl", "ciff", "ir_datasets"} <= set(avail)
    for name in ("ciff", "ir_datasets"):
        if avail[name]:  # pragma: no cover — extra installed in this env
            pytest.skip(f"optional dep for {name} installed")
        with pytest.raises(corpus_io.MissingDependencyError, match="repro\\[corpus\\]"):
            corpus_io.get_reader(name)
    with pytest.raises(KeyError, match="unknown corpus reader"):
        corpus_io.get_reader("nope")


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def test_cli_build_inspect_validate(tmp_path, capsys):
    out = str(tmp_path / "idx")
    rc = index_io_cli([
        "build", "--out", out, "--reader", "synth",
        "--n-docs", "400", "--n-terms", "300", "--n-topics", "4",
        "--n-ranges", "4", "--impact-dtype", "int8", "--shards", "2",
    ])
    assert rc == 0
    assert index_io_cli(["inspect", out]) == 0
    assert "int8" in capsys.readouterr().out
    assert index_io_cli(["validate", out]) == 0
    assert index_io_cli(["validate", out + ".shards2"]) == 0

    # Corruption is a nonzero exit, not a traceback.
    with open(os.path.join(out, "manifest.json"), "w") as f:
        f.write("broken")
    assert index_io_cli(["validate", out]) == 1
    assert index_io_cli(["inspect", out]) == 1


def test_cli_rejects_int8_above_8_bits(tmp_path, capsys):
    """Bad parameter combos exit 1 with a message — before any build work."""
    rc = index_io_cli([
        "build", "--out", str(tmp_path / "x"), "--bits", "9",
        "--impact-dtype", "int8", "--n-docs", "100", "--n-terms", "80",
    ])
    assert rc == 1
    assert "--bits <= 8" in capsys.readouterr().err
