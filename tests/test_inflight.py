"""In-flight (slot-swapping) serving loop: bitwise-resume + saturation.

The tentpole invariant, pinned tier-1: a query served across N slot quanta
via ``batched_traverse_resume`` — including full host<->device carry
round-trips between quanta, mid-flight slot swaps, and budget exits — is
*bitwise identical* to the same query served by one ``device_traverse``
call: same doc ids, scores, work counters, and exit reason.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustered_index import build_index
from repro.core.range_daat import (
    Engine,
    TraverseCarry,
    batched_init_carry,
    batched_traverse_resume,
    carry_done,
)
from repro.data.synth import make_corpus, make_query_log
from repro.serving import (
    BatchEngine,
    BucketSpec,
    DoubleBuffer,
    InflightServer,
    MicroBatchServer,
    SlaBudgeter,
    SlotTable,
    stack_plans,
)

INT32_MAX = 2**31 - 1


def _small_setup(seed: int, n_ranges: int, k: int = 5, n_queries: int = 12):
    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=seed
    )
    idx = build_index(corpus, n_ranges=n_ranges, strategy="clustered")
    eng = Engine(idx, k=k)
    log = make_query_log(corpus, n_queries=n_queries, seed=seed + 1)
    return eng, [log.terms[i] for i in range(log.n_queries)]


def _to_device(carry):
    return jax.tree_util.tree_map(jnp.asarray, carry)


def _to_host(carry):
    return jax.tree_util.tree_map(lambda x: np.array(x), carry)


def _assert_result_matches_single(eng, plan, result, **traverse_kw):
    single = eng.traverse(plan, **traverse_kw)
    sids, svals = eng.topk_docs(single.state)
    assert result.doc_ids.tolist() == sids.tolist()
    assert result.scores.tolist() == svals.tolist()
    assert result.exit_safe == bool(single.exit_safe)
    assert result.exit_budget == bool(single.exit_budget)
    assert result.ranges_processed == int(single.ranges_processed)
    assert result.postings == int(np.asarray(single.state.postings))
    assert result.blocks == int(np.asarray(single.state.blocks))


class FixedBudgeter(SlaBudgeter):
    """Deterministic budgets: every admission gets the same postings cap."""

    def __init__(self, cap: int = INT32_MAX):
        super().__init__(sla_ms=float("inf"))
        self.cap = cap
        self.given: list[int] = []

    def budgets(self, n, plans=None):
        self.given.extend([self.cap] * n)
        return np.full(n, self.cap, dtype=np.int32)


# Deterministic clock shared with the observability substrate, so tests and
# instrumentation agree on what a fake second is (DESIGN.md §13).
from repro.obs import FakeClock  # noqa: E402


# ----------------------------------------------------- core resume invariant


@pytest.mark.parametrize("quantum", [1, 2, 3])
def test_quantum_stepped_resume_matches_single_traverse(quantum):
    """N-quanta resume (host round-trip each step) == one device_traverse."""
    eng, queries = _small_setup(seed=0, n_ranges=6, n_queries=8)
    plans = [eng.plan(q) for q in queries]
    R = eng.index.n_ranges
    width = max(p.blk_tab.shape[1] for p in plans)
    bp = stack_plans(plans, width, batch=len(plans))

    rng = np.random.default_rng(3)
    budgets = rng.choice([120, 700, INT32_MAX], size=len(plans)).astype(np.int64)
    maxr = rng.choice([1, 3, INT32_MAX], size=len(plans)).astype(np.int64)

    carry = batched_init_carry(len(plans), eng.k)
    for _ in range(200):
        out = batched_traverse_resume(
            eng.dix, bp.blk_tab, bp.rest_tab, bp.order, bp.ordered_bounds,
            jnp.asarray(np.clip(budgets, 0, INT32_MAX).astype(np.int32)),
            jnp.asarray(np.clip(maxr, 0, INT32_MAX).astype(np.int32)),
            _to_device(carry), s_pad=eng.s_pad, k=eng.k, quantum=quantum,
        )
        carry = _to_host(out)
        if carry_done(carry, R).all():
            break
    assert carry_done(carry, R).all()

    for i, p in enumerate(plans):
        single = eng.traverse(
            p, budget_postings=int(budgets[i]), max_ranges=int(maxr[i])
        )
        assert carry.state.vals[i].tolist() == np.asarray(single.state.vals).tolist()
        assert carry.state.ids[i].tolist() == np.asarray(single.state.ids).tolist()
        assert int(carry.i[i]) == int(single.ranges_processed)
        assert bool(carry.exit_safe[i]) == bool(single.exit_safe)
        assert bool(carry.exit_budget[i]) == bool(single.exit_budget)
        assert int(carry.state.postings[i]) == int(np.asarray(single.state.postings))
        assert int(carry.state.blocks[i]) == int(np.asarray(single.state.blocks))


def test_carry_roundtrip_is_bitwise():
    """host->device->host round-trip preserves every carry leaf exactly."""
    eng, queries = _small_setup(seed=2, n_ranges=4, n_queries=4)
    plans = [eng.plan(q) for q in queries]
    width = max(p.blk_tab.shape[1] for p in plans)
    bp = stack_plans(plans, width, batch=len(plans))
    b = jnp.full(len(plans), INT32_MAX, jnp.int32)

    carry = batched_init_carry(len(plans), eng.k)
    out = batched_traverse_resume(
        eng.dix, bp.blk_tab, bp.rest_tab, bp.order, bp.ordered_bounds,
        b, b, _to_device(carry), s_pad=eng.s_pad, k=eng.k, quantum=2,
    )
    host = _to_host(out)
    back = _to_host(_to_device(host))
    for leaf_a, leaf_b in zip(
        jax.tree_util.tree_leaves(host), jax.tree_util.tree_leaves(back)
    ):
        assert leaf_a.dtype == leaf_b.dtype
        assert np.array_equal(leaf_a, leaf_b)


def test_parked_lanes_do_no_work():
    """A parked (vacant) lane's carry is inert across any number of quanta."""
    eng, queries = _small_setup(seed=4, n_ranges=4, n_queries=4)
    plans = [eng.plan(q) for q in queries]
    width = max(p.blk_tab.shape[1] for p in plans)
    bp = stack_plans(plans[:2], width, batch=4)  # lanes 2,3 are dummies
    b = jnp.full(4, INT32_MAX, jnp.int32)

    carry = batched_init_carry(4, eng.k, parked=True)
    # Un-park only the two real lanes.
    carry.exit_budget[:2] = False
    for _ in range(10):
        carry = _to_host(batched_traverse_resume(
            eng.dix, bp.blk_tab, bp.rest_tab, bp.order, bp.ordered_bounds,
            b, b, _to_device(carry), s_pad=eng.s_pad, k=eng.k, quantum=1,
        ))
    for lane in (2, 3):
        assert int(carry.i[lane]) == 0
        assert int(carry.state.postings[lane]) == 0
        assert np.all(carry.state.ids[lane] == -1)


# --------------------------------------------------------- slot-table staging


def test_slot_table_write_clear_grow():
    eng, queries = _small_setup(seed=6, n_ranges=4, n_queries=3)
    plans = [eng.plan(q) for q in queries]
    R = eng.index.n_ranges
    w = max(p.blk_tab.shape[1] for p in plans)
    tab = SlotTable(3, R, w)
    tab.write_lane(0, plans[0], budget=500)
    assert tab.valid[0] and tab.budget[0] == 500
    assert np.array_equal(tab.order[0], plans[0].order_host)
    tab.clear_lane(0)
    assert not tab.valid[0] and tab.budget[0] == 0
    assert np.all(tab.blk[0] == -1) and np.all(tab.bounds[0] == 0)

    tab.write_lane(1, plans[1], budget=7, max_ranges=2)
    grown = tab.grow_width(2 * w)
    assert grown.width == 2 * w
    assert np.array_equal(grown.blk[1, :, :w], tab.blk[1])
    assert np.all(grown.blk[1, :, w:] == -1)  # new columns are padding
    assert grown.budget[1] == 7 and grown.maxr[1] == 2 and grown.valid[1]

    with pytest.raises(ValueError):
        tab.grow_width(w // 2)
    with pytest.raises(ValueError):
        SlotTable(0, R, w)


def test_double_buffer_swap_carries_live_state():
    eng, queries = _small_setup(seed=6, n_ranges=4, n_queries=2)
    plan = eng.plan(queries[0])
    w = plan.blk_tab.shape[1]
    buf = DoubleBuffer(2, eng.index.n_ranges, w)
    buf.back.write_lane(0, plan, budget=123)
    buf.swap()
    # The admission went live, and the new back mirrors the front.
    assert buf.front.valid[0] and buf.front.budget[0] == 123
    assert buf.back.valid[0] and buf.back.budget[0] == 123
    buf.back.clear_lane(0)
    assert buf.front.valid[0]  # in-flight table untouched by back writes
    buf.swap()
    assert not buf.front.valid[0]


# ------------------------------------------------------------ server parity


def test_inflight_server_bitwise_parity_unbounded():
    eng, queries = _small_setup(seed=8, n_ranges=4, n_queries=12)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    srv = InflightServer(beng, SlaBudgeter(sla_ms=float("inf")), n_slots=4)
    served = srv.replay(queries)
    assert sorted(s.rid for s in served) == list(range(len(queries)))
    for s in served:
        _assert_result_matches_single(eng, eng.plan(queries[s.rid]), s.result)


def test_inflight_server_bitwise_parity_budgeted():
    """Admission-time budgets behave exactly like device_traverse budgets."""
    eng, queries = _small_setup(seed=9, n_ranges=6, n_queries=10)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    budgeter = FixedBudgeter(cap=100)
    srv = InflightServer(beng, budgeter, n_slots=4, quantum=2)
    served = srv.replay(queries)
    assert len(budgeter.given) == len(queries)
    for s in served:
        _assert_result_matches_single(
            eng, eng.plan(queries[s.rid]), s.result, budget_postings=100
        )
    assert any(s.result.exit_reason == "budget" for s in served)


def test_slot_swap_happens_mid_flight():
    """Queries admit into freed slots while others are still in flight."""
    eng, queries = _small_setup(seed=10, n_ranges=6, n_queries=10)
    beng = BatchEngine(eng, BucketSpec(max_batch=4))
    srv = InflightServer(beng, SlaBudgeter(sla_ms=float("inf")), n_slots=2)
    for q in queries:
        srv.submit(q)
    swapped = False
    served = []
    while srv.pending or srv.active:
        done = srv.step()
        if done and srv.active > 0:
            swapped = True  # a slot retired while its batchmate kept flying
        served.extend(done)
    assert swapped
    assert srv.admissions == len(queries) > srv.n_slots
    # One persistent program: slot swaps never recompile.
    assert len(srv.compiled_shapes) == 1
    for s in served:
        _assert_result_matches_single(eng, eng.plan(queries[s.rid]), s.result)


# --------------------------------------------------------------- saturation


def test_saturation_bitwise_both_servers():
    """Offered load >> capacity: every query's result stays bitwise-exact."""
    eng, queries = _small_setup(seed=12, n_ranges=4, n_queries=24)
    cap = 600

    beng = BatchEngine(eng, BucketSpec(max_batch=4))
    micro = MicroBatchServer(beng, FixedBudgeter(cap=cap), max_batch=4)
    for q in queries:  # burst far beyond one batch
        micro.submit(q)
    served_m = []
    while micro.pending:
        served_m.extend(micro.drain_once())

    infl = InflightServer(
        BatchEngine(eng, BucketSpec(max_batch=4)), FixedBudgeter(cap=cap),
        n_slots=4,
    )
    served_i = infl.replay(queries)

    for served in (served_m, served_i):
        assert sorted(s.rid for s in served) == list(range(len(queries)))
        for s in served:
            _assert_result_matches_single(
                eng, eng.plan(queries[s.rid]), s.result, budget_postings=cap
            )


def test_saturation_queue_bounded_under_tightening():
    """Sustained arrivals: the budgeter tightens and the queue stays bounded."""
    eng, queries = _small_setup(seed=14, n_ranges=4, n_queries=12)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    clock = FakeClock(dt=0.010)  # every reading +10ms: e2e latencies blow up
    budgeter = SlaBudgeter(sla_ms=5.0, rate=100.0)
    srv = MicroBatchServer(beng, budgeter, max_batch=8, clock=clock)

    depths = []
    qi = 0
    for _ in range(12):  # arrivals every round, service every round
        for _ in range(4):
            srv.submit(queries[qi % len(queries)])
            qi += 1
        srv.drain_once()
        depths.append(srv.pending)
    # Service rate (8/round) beats arrivals (4/round): depth bounded, and
    # the overload drove Eq. (7) to tighten rather than relax.
    assert max(depths) <= 8
    assert depths[-1] == 0
    assert budgeter.policy.alpha > 1.0

    infl = InflightServer(
        BatchEngine(eng, BucketSpec(max_batch=8)),
        SlaBudgeter(sla_ms=5.0, rate=100.0), n_slots=8,
        clock=FakeClock(dt=0.010),
    )
    depths = []
    qi = 0
    for _ in range(16):
        for _ in range(4):
            infl.submit(queries[qi % len(queries)])
            qi += 1
        infl.step()
        depths.append(infl.pending + infl.active)
    infl.run_until_idle()
    assert max(depths) <= 8 + 4 * 16  # never exceeds total offered
    assert infl.budgeter.policy.alpha > 1.0
    assert infl.pending == 0 and infl.active == 0


def test_latency_attribution_monotone_with_queue_position():
    """Identical queries arriving at one instant, FIFO service: attributed
    latency is non-decreasing with queue position (both servers)."""
    eng, queries = _small_setup(seed=16, n_ranges=4, n_queries=2)
    q = queries[0]

    beng = BatchEngine(eng, BucketSpec(max_batch=4))
    clock = FakeClock(dt=0.0)  # frozen during the arrival burst
    micro = MicroBatchServer(
        beng, SlaBudgeter(sla_ms=float("inf")), max_batch=4, clock=clock
    )
    for _ in range(12):
        micro.submit(q)
    clock.dt = 0.001  # time moves once service starts
    served = []
    while micro.pending:
        served.extend(micro.drain_once())
    lat = [s.latency_ms for s in sorted(served, key=lambda s: s.rid)]
    assert all(b >= a for a, b in zip(lat, lat[1:])), lat
    assert lat[-1] > lat[0]  # deeper queue position paid real queue wait

    clock = FakeClock(dt=0.0)
    infl = InflightServer(
        BatchEngine(eng, BucketSpec(max_batch=4)),
        SlaBudgeter(sla_ms=float("inf")), n_slots=4, clock=clock,
    )
    for _ in range(12):
        infl.submit(q)
    clock.dt = 0.001
    served = infl.run_until_idle()
    lat = [s.latency_ms for s in sorted(served, key=lambda s: s.rid)]
    assert all(b >= a for a, b in zip(lat, lat[1:])), lat
    assert lat[-1] > lat[0]


# ------------------------------------------- queue-aware Reactive feedback


def test_microbatch_overload_feeds_end_to_end_latency_to_policy():
    """Queue wait counts: device-fast batches behind a deep queue must
    still register as SLA misses and tighten budgets (Eq. 7)."""
    eng, queries = _small_setup(seed=18, n_ranges=4, n_queries=12)
    beng = BatchEngine(eng, BucketSpec(max_batch=2))
    clock = FakeClock(dt=0.010)
    # Each dispatch reads the clock twice -> batch_ms == 10 < sla == 50.
    # But a 12-deep queue drained 2 at a time means most queries wait far
    # longer than 50ms end-to-end.
    budgeter = SlaBudgeter(sla_ms=50.0, rate=1e6)
    srv = MicroBatchServer(beng, budgeter, max_batch=2, clock=clock)
    for q in queries:
        srv.submit(q)
    served = []
    while srv.pending:
        served.extend(srv.drain_once())

    assert all(s.latency_ms > 10.0 for s in served[2:])
    assert any(s.latency_ms > 50.0 for s in served)
    # Pre-fix behaviour: policy only ever saw batch_ms=10 (< sla) and alpha
    # would *relax* below 1. Queue-aware feedback must tighten it instead.
    assert budgeter.policy.alpha > 1.0


def test_budgeter_latencies_override_device_time():
    fast_device = SlaBudgeter(sla_ms=50.0)
    fast_device.observe(
        elapsed_ms=10.0, total_postings=1000, n=2, latencies_ms=[120.0, 130.0]
    )
    assert fast_device.policy.alpha > 1.0  # two e2e misses despite fast device

    rate_only = SlaBudgeter(sla_ms=50.0)
    a0 = rate_only.policy.alpha
    rate_only.observe(
        elapsed_ms=10.0, total_postings=1000, n=2, latencies_ms=[]
    )
    assert rate_only.policy.alpha == a0  # empty list: rate EWMA only
