"""Embedding-bag kernel + EmbeddingBag semantics vs oracle."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.embedding_bag.ops import bag_reduce
from repro.models.embedding import embedding_bag, embedding_bag_ragged


@pytest.mark.parametrize("B,L,D", [(4, 3, 8), (17, 20, 32), (128, 200, 64), (33, 7, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bag_reduce_matches_ref(B, L, D, dtype):
    rng = np.random.default_rng(B * 1000 + L)
    rows = rng.normal(0, 1, size=(B, L, D)).astype(np.float32)
    w = rng.normal(0, 1, size=(B, L)).astype(np.float32)
    got = bag_reduce(jnp.asarray(rows, dtype), jnp.asarray(w, dtype), impl="pallas")
    ref = bag_reduce(jnp.asarray(rows, dtype), jnp.asarray(w, dtype), impl="xla")
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_embedding_bag_padding_and_mean():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([[1, 2, -1], [3, -1, -1]], jnp.int32)
    out_sum = embedding_bag(table, ids, combine="sum")
    np.testing.assert_allclose(np.asarray(out_sum[0]), table[1] + table[2])
    np.testing.assert_allclose(np.asarray(out_sum[1]), table[3])
    out_mean = embedding_bag(table, ids, combine="mean")
    np.testing.assert_allclose(np.asarray(out_mean[0]), (table[1] + table[2]) / 2)
    np.testing.assert_allclose(np.asarray(out_mean[1]), table[3])


def test_embedding_bag_pallas_path_matches_xla():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 16)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, 50, size=(8, 5)), jnp.int32)
    a = embedding_bag(table, ids, impl="xla")
    b = embedding_bag(table, ids, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_bags=st.integers(1, 12),
    n_ids=st.integers(1, 64),
)
def test_property_ragged_equals_dense_grouping(seed, n_bags, n_ids):
    """Ragged segment-sum bags == manual per-bag sums."""
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(30, 4)).astype(np.float32)
    flat = rng.integers(0, 30, size=n_ids).astype(np.int32)
    seg = np.sort(rng.integers(0, n_bags, size=n_ids)).astype(np.int32)
    out = embedding_bag_ragged(jnp.asarray(table), jnp.asarray(flat), jnp.asarray(seg), n_bags)
    expect = np.zeros((n_bags, 4), np.float32)
    for i, s in zip(flat, seg):
        expect[s] += table[i]
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)
