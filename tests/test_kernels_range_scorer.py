"""Pallas range-scorer kernel vs pure-jnp oracle: shape/dtype sweeps.

The kernel runs in interpret mode (CPU container; TPU is the target). All
comparisons are exact — integer impacts accumulated in fp32 stay below 2^24.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.range_scorer import ref
from repro.kernels.range_scorer.kernel import scatter_accumulate_pallas
from repro.kernels.range_scorer.ops import score_blocks


def _random_case(rng, nnz, n_blocks, s_range):
    docs = np.sort(rng.integers(0, s_range, size=nnz)).astype(np.int32)
    imps = rng.integers(1, 256, size=nnz).astype(np.int32)
    starts = rng.integers(0, max(nnz - ref.BLOCK, 1), size=n_blocks).astype(np.int64)
    lens = rng.integers(1, ref.BLOCK + 1, size=n_blocks).astype(np.int32)
    lens = np.minimum(lens, nnz - starts).astype(np.int32)
    keep = rng.random(n_blocks) < 0.8
    return docs, imps, starts, lens, keep


@pytest.mark.parametrize("s_pad", [128, 384, 1024])
@pytest.mark.parametrize("n_blocks", [1, 7, 32])
def test_pallas_matches_ref(s_pad, n_blocks):
    rng = np.random.default_rng(s_pad * 1000 + n_blocks)
    docs, imps, starts, lens, keep = _random_case(rng, 5000, n_blocks, s_pad)
    r0 = jnp.int32(0)
    expect = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), r0, s_pad=s_pad, impl="xla",
    )
    got = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), r0, s_pad=s_pad, impl="pallas",
    )
    np.testing.assert_array_equal(np.asarray(expect), np.asarray(got))


@pytest.mark.parametrize("s_tile,p_tile", [(128, 128), (256, 512), (512, 1024)])
def test_pallas_tile_sweep(s_tile, p_tile):
    rng = np.random.default_rng(s_tile + p_tile)
    P, S = 3000, 900
    ids = rng.integers(-1, S, size=P).astype(np.int32)
    vals = rng.integers(0, 256, size=P).astype(np.int32)
    vals[ids < 0] = 0
    got = scatter_accumulate_pallas(
        jnp.asarray(ids), jnp.asarray(vals), s_pad=S, s_tile=s_tile, p_tile=p_tile
    )
    expect = np.zeros(S, np.int64)
    np.add.at(expect, ids[ids >= 0], vals[ids >= 0])
    np.testing.assert_array_equal(np.asarray(got, np.int64), expect)


def test_all_pruned_gives_zero():
    rng = np.random.default_rng(0)
    docs, imps, starts, lens, _ = _random_case(rng, 1000, 4, 256)
    out = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.zeros(4, bool), jnp.int32(0),
        s_pad=256, impl="pallas",
    )
    assert int(jnp.sum(out)) == 0


def test_padding_blocks_ignored():
    rng = np.random.default_rng(1)
    docs, imps, starts, lens, keep = _random_case(rng, 1000, 4, 256)
    starts2 = np.concatenate([starts, [-1, -1]])
    lens2 = np.concatenate([lens, [128, 128]]).astype(np.int32)
    keep2 = np.concatenate([keep, [True, True]])
    a = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), jnp.int32(0), s_pad=256,
    )
    b = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts2),
        jnp.asarray(lens2), jnp.asarray(keep2), jnp.int32(0), s_pad=256,
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_compiled_pallas_backend_smoke():
    """Tier-2 de-risk: the kernel with ``interpret=False`` on a compiled
    Pallas backend (TPU/GPU), skip-guarded on CPU where only interpret mode
    exists. The flag is plumbed through ``Engine(impl="pallas",
    interpret=False)``, so the full compiled path is this one switch."""
    import jax

    if jax.default_backend() == "cpu":
        pytest.skip("no compiled Pallas backend on CPU (interpret-only)")

    # Kernel level: compiled == oracle scatter.
    rng = np.random.default_rng(0)
    P, S = 4000, 1024
    ids = rng.integers(-1, S, size=P).astype(np.int32)
    vals = rng.integers(0, 256, size=P).astype(np.int32)
    vals[ids < 0] = 0
    got = scatter_accumulate_pallas(
        jnp.asarray(ids), jnp.asarray(vals), s_pad=S, interpret=False
    )
    expect = np.zeros(S, np.int64)
    np.add.at(expect, ids[ids >= 0], vals[ids >= 0])
    np.testing.assert_array_equal(np.asarray(got, np.int64), expect)

    # Engine level: the compiled Pallas scorer is one switch away and
    # bitwise-identical to the XLA reference over whole-query traversals.
    from repro.core.clustered_index import build_index
    from repro.core.range_daat import Engine
    from repro.data.synth import make_corpus, make_query_log

    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=5
    )
    idx = build_index(corpus, n_ranges=6, strategy="clustered")
    ref_eng = Engine(idx, k=10, impl="xla")
    compiled = Engine(idx, k=10, impl="pallas", interpret=False)
    assert compiled.interpret is False
    log = make_query_log(corpus, n_queries=6, seed=6)
    for i in range(log.n_queries):
        plan_r = ref_eng.plan(log.terms[i])
        plan_c = compiled.plan(log.terms[i])
        a = ref_eng.traverse(plan_r)
        b = compiled.traverse(plan_c)
        rids, rvals = ref_eng.topk_docs(a.state)
        cids, cvals = compiled.topk_docs(b.state)
        assert cids.tolist() == rids.tolist()
        assert cvals.tolist() == rvals.tolist()


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s_pad=st.sampled_from([128, 256, 640]),
    n_blocks=st.integers(1, 24),
    range_start=st.integers(0, 100),
)
def test_property_pallas_equals_scatter(seed, s_pad, n_blocks, range_start):
    """Property: kernel == oracle for arbitrary block geometry + offsets."""
    rng = np.random.default_rng(seed)
    nnz = int(rng.integers(200, 4000))
    docs = np.sort(rng.integers(range_start, range_start + s_pad, size=nnz)).astype(
        np.int32
    )
    imps = rng.integers(1, 256, size=nnz).astype(np.int32)
    starts = rng.integers(0, nnz, size=n_blocks).astype(np.int64)
    lens = np.minimum(
        rng.integers(1, ref.BLOCK + 1, size=n_blocks), nnz - starts
    ).astype(np.int32)
    keep = rng.random(n_blocks) < 0.7
    args = (
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), jnp.int32(range_start),
    )
    a = score_blocks(*args, s_pad=s_pad, impl="xla")
    b = score_blocks(*args, s_pad=s_pad, impl="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
