"""Pallas range-scorer kernel vs pure-jnp oracle: shape/dtype sweeps.

The kernel runs in interpret mode (CPU container; TPU is the target). All
comparisons are exact — integer impacts accumulated in fp32 stay below 2^24.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.clustered_index import pack_dir_entries, pack_docs
from repro.kernels.range_scorer import ref
from repro.kernels.range_scorer.kernel import (
    scatter_accumulate_pallas,
    unpack_locals_pallas,
)
from repro.kernels.range_scorer.ops import score_blocks


def _random_case(rng, nnz, n_blocks, s_range):
    docs = np.sort(rng.integers(0, s_range, size=nnz)).astype(np.int32)
    imps = rng.integers(1, 256, size=nnz).astype(np.int32)
    starts = rng.integers(0, max(nnz - ref.BLOCK, 1), size=n_blocks).astype(np.int64)
    lens = rng.integers(1, ref.BLOCK + 1, size=n_blocks).astype(np.int32)
    lens = np.minimum(lens, nnz - starts).astype(np.int32)
    keep = rng.random(n_blocks) < 0.8
    return docs, imps, starts, lens, keep


@pytest.mark.parametrize("s_pad", [128, 384, 1024])
@pytest.mark.parametrize("n_blocks", [1, 7, 32])
def test_pallas_matches_ref(s_pad, n_blocks):
    rng = np.random.default_rng(s_pad * 1000 + n_blocks)
    docs, imps, starts, lens, keep = _random_case(rng, 5000, n_blocks, s_pad)
    r0 = jnp.int32(0)
    expect = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), r0, s_pad=s_pad, impl="xla",
    )
    got = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), r0, s_pad=s_pad, impl="pallas",
    )
    np.testing.assert_array_equal(np.asarray(expect), np.asarray(got))


@pytest.mark.parametrize("s_tile,p_tile", [(128, 128), (256, 512), (512, 1024)])
def test_pallas_tile_sweep(s_tile, p_tile):
    rng = np.random.default_rng(s_tile + p_tile)
    P, S = 3000, 900
    ids = rng.integers(-1, S, size=P).astype(np.int32)
    vals = rng.integers(0, 256, size=P).astype(np.int32)
    vals[ids < 0] = 0
    got = scatter_accumulate_pallas(
        jnp.asarray(ids), jnp.asarray(vals), s_pad=S, s_tile=s_tile, p_tile=p_tile
    )
    expect = np.zeros(S, np.int64)
    np.add.at(expect, ids[ids >= 0], vals[ids >= 0])
    np.testing.assert_array_equal(np.asarray(got, np.int64), expect)


def test_all_pruned_gives_zero():
    rng = np.random.default_rng(0)
    docs, imps, starts, lens, _ = _random_case(rng, 1000, 4, 256)
    out = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.zeros(4, bool), jnp.int32(0),
        s_pad=256, impl="pallas",
    )
    assert int(jnp.sum(out)) == 0


def test_padding_blocks_ignored():
    rng = np.random.default_rng(1)
    docs, imps, starts, lens, keep = _random_case(rng, 1000, 4, 256)
    starts2 = np.concatenate([starts, [-1, -1]])
    lens2 = np.concatenate([lens, [128, 128]]).astype(np.int32)
    keep2 = np.concatenate([keep, [True, True]])
    a = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), jnp.int32(0), s_pad=256,
    )
    b = score_blocks(
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts2),
        jnp.asarray(lens2), jnp.asarray(keep2), jnp.int32(0), s_pad=256,
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_compiled_pallas_backend_smoke():
    """Tier-2 de-risk: the kernel with ``interpret=False`` on a compiled
    Pallas backend (TPU/GPU), skip-guarded on CPU where only interpret mode
    exists. The flag is plumbed through ``Engine(impl="pallas",
    interpret=False)``, so the full compiled path is this one switch."""
    import jax

    if jax.default_backend() == "cpu":
        pytest.skip("no compiled Pallas backend on CPU (interpret-only)")

    # Kernel level: compiled == oracle scatter.
    rng = np.random.default_rng(0)
    P, S = 4000, 1024
    ids = rng.integers(-1, S, size=P).astype(np.int32)
    vals = rng.integers(0, 256, size=P).astype(np.int32)
    vals[ids < 0] = 0
    got = scatter_accumulate_pallas(
        jnp.asarray(ids), jnp.asarray(vals), s_pad=S, interpret=False
    )
    expect = np.zeros(S, np.int64)
    np.add.at(expect, ids[ids >= 0], vals[ids >= 0])
    np.testing.assert_array_equal(np.asarray(got, np.int64), expect)

    # Engine level: the compiled Pallas scorer is one switch away and
    # bitwise-identical to the XLA reference over whole-query traversals.
    from repro.core.clustered_index import build_index
    from repro.core.range_daat import Engine
    from repro.data.synth import make_corpus, make_query_log

    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=5
    )
    idx = build_index(corpus, n_ranges=6, strategy="clustered")
    ref_eng = Engine(idx, k=10, impl="xla")
    compiled = Engine(idx, k=10, impl="pallas", interpret=False)
    assert compiled.interpret is False
    log = make_query_log(corpus, n_queries=6, seed=6)
    for i in range(log.n_queries):
        plan_r = ref_eng.plan(log.terms[i])
        plan_c = compiled.plan(log.terms[i])
        a = ref_eng.traverse(plan_r)
        b = compiled.traverse(plan_c)
        rids, rvals = ref_eng.topk_docs(a.state)
        cids, cvals = compiled.topk_docs(b.state)
        assert cids.tolist() == rids.tolist()
        assert cvals.tolist() == rvals.tolist()


# --------------------------------------------------- packed docid decoding


def _packed_pool(rng, n_pool, max_deltas=(0, 1, 200, 255, 300, 70_000)):
    """Pool of contiguous blocks spanning every pack width, pre-packed."""
    blk_len = rng.integers(1, ref.BLOCK + 1, size=n_pool).astype(np.int64)
    blk_start = np.cumsum(blk_len) - blk_len
    chunks = []
    for length in blk_len:
        md = int(rng.choice(max_deltas))
        d = np.zeros(int(length), np.int64)
        if md:
            d[1:] = rng.integers(0, md + 1, size=int(length) - 1)
        chunks.append(int(rng.integers(0, 500)) + np.cumsum(d))
    docs = np.concatenate(chunks).astype(np.int64)
    packed = pack_docs(docs, blk_start, blk_len)
    imps = rng.integers(1, 256, size=docs.shape[0]).astype(np.int32)
    return docs, imps, blk_start, blk_len, packed


def _select(packed, blk_start, blk_len, sel):
    """Per-query directory columns for the selected blocks (engine layout)."""
    return dict(
        starts=jnp.asarray(blk_start[sel], jnp.int32),
        lens=jnp.asarray(blk_len[sel], jnp.int32),
        pack_dir=jnp.asarray(pack_dir_entries(packed)[sel], jnp.int32),
        pack_firsts=jnp.asarray(packed.blk_first[sel], jnp.int32),
    )


@pytest.mark.parametrize("seed", [0, 3, 9])
def test_pallas_packed_decode_matches_oracle_across_widths(seed):
    """Kernel decode == pure-jnp oracle for every width in one dispatch."""
    rng = np.random.default_rng(seed)
    _, imps, blk_start, blk_len, packed = _packed_pool(rng, 24)
    assert {0, 4, 8, 16, 32} <= set(packed.blk_width.tolist())
    sel = rng.integers(0, 24, size=17)  # duplicates allowed, like a query
    cols = _select(packed, blk_start, blk_len, sel)
    keep = jnp.asarray(rng.random(17) < 0.8)
    words = jnp.asarray(packed.words)
    r0 = jnp.int32(int(rng.integers(0, 100)))
    oracle_local, _ = ref.gather_block_postings_packed(
        words, jnp.asarray(imps), cols["starts"], cols["lens"],
        cols["pack_dir"], cols["pack_firsts"], keep, r0,
    )
    got = unpack_locals_pallas(
        words, cols["starts"], cols["lens"],
        cols["pack_dir"], cols["pack_firsts"], keep, r0,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle_local))


def test_pallas_packed_decode_pruned_and_padding_rows():
    """keep=False rows and starts==-1 padding rows decode to all -1."""
    rng = np.random.default_rng(5)
    _, imps, blk_start, blk_len, packed = _packed_pool(rng, 8)
    sel = np.arange(8)
    cols = _select(packed, blk_start, blk_len, sel)
    # Engine-style padding rows: starts == -1, directory columns carry the
    # clamped gather of a real block (index 0), exactly what safe_ids does.
    starts = jnp.concatenate([cols["starts"], jnp.asarray([-1, -1], jnp.int32)])
    pad = lambda c: jnp.concatenate([c, c[:1], c[:1]])
    lens = pad(cols["lens"])
    pd, pf = pad(cols["pack_dir"]), pad(cols["pack_firsts"])
    words = jnp.asarray(packed.words)
    r0 = jnp.int32(0)

    all_pruned = jnp.zeros(10, bool)
    got = unpack_locals_pallas(words, starts, lens, pd, pf, all_pruned, r0)
    assert np.all(np.asarray(got) == -1)

    keep = jnp.ones(10, bool)
    got = unpack_locals_pallas(words, starts, lens, pd, pf, keep, r0)
    oracle_local, _ = ref.gather_block_postings_packed(
        words, jnp.asarray(imps), starts, lens, pd, pf, keep, r0
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle_local))
    assert np.all(np.asarray(got).reshape(10, ref.BLOCK)[8:] == -1)


def test_score_blocks_packed_parity_straddling_width_change():
    """One scored range spanning a width change: all three paths agree."""
    rng = np.random.default_rng(11)
    # Narrow deltas only so every docid stays inside a modest accumulator.
    docs, imps, blk_start, blk_len, packed = _packed_pool(
        rng, 12, max_deltas=(0, 1, 7)
    )
    assert len(set(packed.blk_width.tolist())) >= 2  # widths change mid-range
    s_pad = int(docs.max()) + 1
    sel = np.arange(12)
    cols = _select(packed, blk_start, blk_len, sel)
    keep = jnp.asarray(rng.random(12) < 0.9)
    words = jnp.asarray(packed.words)
    pk = dict(
        pack_words=words, pack_dir=cols["pack_dir"],
        pack_firsts=cols["pack_firsts"],
    )
    for r0 in (0, 3):
        base = score_blocks(
            jnp.asarray(docs, jnp.int32), jnp.asarray(imps), cols["starts"],
            cols["lens"], keep, jnp.int32(r0), s_pad=s_pad, impl="xla",
        )
        for impl in ("xla", "pallas"):
            got = score_blocks(
                jnp.zeros((1,), jnp.int32), jnp.asarray(imps), cols["starts"],
                cols["lens"], keep, jnp.int32(r0), s_pad=s_pad, impl=impl,
                docs_format="packed", **pk,
            )
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(base), err_msg=f"{impl} r0={r0}"
            )


def test_score_blocks_packed_requires_directory():
    with pytest.raises(ValueError, match="pack_"):
        score_blocks(
            jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
            jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.int32),
            jnp.ones((1,), bool), jnp.int32(0), s_pad=128,
            docs_format="packed",
        )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s_pad=st.sampled_from([128, 256, 640]),
    n_blocks=st.integers(1, 24),
    range_start=st.integers(0, 100),
)
def test_property_pallas_equals_scatter(seed, s_pad, n_blocks, range_start):
    """Property: kernel == oracle for arbitrary block geometry + offsets."""
    rng = np.random.default_rng(seed)
    nnz = int(rng.integers(200, 4000))
    docs = np.sort(rng.integers(range_start, range_start + s_pad, size=nnz)).astype(
        np.int32
    )
    imps = rng.integers(1, 256, size=nnz).astype(np.int32)
    starts = rng.integers(0, nnz, size=n_blocks).astype(np.int64)
    lens = np.minimum(
        rng.integers(1, ref.BLOCK + 1, size=n_blocks), nnz - starts
    ).astype(np.int32)
    keep = rng.random(n_blocks) < 0.7
    args = (
        jnp.asarray(docs), jnp.asarray(imps), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(keep), jnp.int32(range_start),
    )
    a = score_blocks(*args, s_pad=s_pad, impl="xla")
    b = score_blocks(*args, s_pad=s_pad, impl="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
