"""RBO / RBP / AP sanity and known-value tests."""

from __future__ import annotations

import numpy as np

from repro.core.metrics import average_precision, rbo, rbp


def test_rbo_identical_is_one():
    assert rbo([1, 2, 3, 4], [1, 2, 3, 4]) == 1.0


def test_rbo_disjoint_is_zero():
    assert rbo([1, 2, 3], [4, 5, 6], extrapolate=False) == 0.0


def test_rbo_partial_between():
    v = rbo([1, 2, 3, 4], [1, 2, 4, 3], phi=0.9)
    assert 0.0 < v <= 1.0


def test_rbo_monotone_in_agreement():
    base = [1, 2, 3, 4, 5]
    closer = [1, 2, 3, 5, 4]
    farther = [5, 4, 3, 2, 1]
    assert rbo(base, closer) > rbo(base, farther)


def test_rbp_known_value():
    # Single relevant doc at rank 1: RBP = (1-phi).
    assert np.isclose(rbp([7], {7: 1.0}, phi=0.8), 0.2)
    # Ranks 1 and 2 relevant: (1-phi)(1 + phi).
    assert np.isclose(rbp([7, 8], [7, 8], phi=0.8), 0.2 * 1.8)


def test_ap_perfect():
    assert average_precision([1, 2, 3], [1, 2, 3]) == 1.0


def test_ap_half():
    # Relevant = {1}; ranking = [2, 1] -> AP = 1/2.
    assert np.isclose(average_precision([2, 1], [1]), 0.5)
