"""Observability substrate (repro.obs): metrics, traces, export, report.

Three contracts pinned here, per ISSUE 8 / DESIGN.md §13:

  * **results neutrality** — serving with full instrumentation (metrics +
    tracing at sample rate 1.0, durable JSONL sink) is *bitwise identical*
    to serving with the no-op handle: same doc ids, scores, and exit
    reasons on both the micro-batch and in-flight paths;
  * **exit-reason conservation** — telemetry exit counters sum to the
    number of queries served and match the returned per-query reasons as
    a multiset, at every layer (Engine, BatchEngine, both servers,
    ShardedEngine per shard);
  * **substrate unit behaviour** — log2 histogram bucketing/percentiles,
    deterministic trace sampling, torn-tail JSONL recovery, Prometheus
    exposition shape, and the report CLI's summary math.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from differential import assert_exit_reason_conservation
from repro.core.clustered_index import build_index
from repro.core.range_daat import Engine, exit_reason
from repro.data.synth import make_corpus, make_query_log
from repro.obs import (
    N_BUCKETS,
    FakeClock,
    Instrumentation,
    MetricsRegistry,
    NOOP,
    Tracer,
    TraceSink,
    json_snapshot,
    prometheus_text,
    read_traces,
    render,
    summarize,
)
from repro.obs.metrics import BUCKET_EDGES, bucket_index
from repro.obs.trace import sampled
from repro.serving import (
    BatchEngine,
    BucketSpec,
    InflightServer,
    MicroBatchServer,
    ShardedEngine,
    SlaBudgeter,
    result_exit_reason,
)


def _small_setup(seed: int, n_ranges: int, k: int = 5, n_queries: int = 12):
    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=seed
    )
    idx = build_index(corpus, n_ranges=n_ranges, strategy="clustered")
    eng = Engine(idx, k=k)
    log = make_query_log(corpus, n_queries=n_queries, seed=seed + 1)
    return eng, [log.terms[i] for i in range(log.n_queries)]


# --------------------------------------------------------------------------
# metrics substrate
# --------------------------------------------------------------------------


def test_bucket_index_edges():
    assert bucket_index(-3.0) == 0
    assert bucket_index(0.0) == 0
    assert bucket_index(0.9) == 0
    assert bucket_index(1.0) == 1
    assert bucket_index(1.9) == 1  # int() floors into [1, 2)
    assert bucket_index(2.0) == 2
    assert bucket_index(3.0) == 2
    assert bucket_index(4.0) == 3
    assert bucket_index(2.0**62) == N_BUCKETS - 1
    assert bucket_index(float(2**200)) == N_BUCKETS - 1  # overflow clamps
    assert BUCKET_EDGES[-1] == float("inf")


def test_counter_gauge_label_series():
    reg = MetricsRegistry()
    c = reg.counter("served")
    c.inc(reason="safe")
    c.inc(2.0, reason="budget")
    c.inc(reason="safe")
    assert c.value(reason="safe") == 2.0
    assert c.value(reason="budget") == 2.0
    assert c.value(reason="down") == 0.0
    assert c.total() == 4.0
    g = reg.gauge("alpha")
    g.set(1.5)
    g.set(2.5)  # last write wins
    assert g.value() == 2.5
    # get-or-create is idempotent per name; kind mismatch is an error.
    assert reg.counter("served") is c
    with pytest.raises(TypeError):
        reg.gauge("served")


def test_histogram_percentiles_one_octave():
    reg = MetricsRegistry()
    h = reg.histogram("latency_ms")
    values = [0.4, 1.5, 3.0, 3.0, 6.0, 12.0, 100.0, 900.0]
    for v in values:
        h.observe(v)
    assert h.count() == len(values)
    assert h.mean() == pytest.approx(float(np.mean(values)))
    # Log2 buckets: each percentile lands within one octave of the truth.
    for p in (50.0, 95.0, 99.0):
        got = h.percentile(p)
        true = float(np.percentile(values, p))
        assert true / 2.0 <= got <= true * 2.0 + 1.0, (p, got, true)
    snap = h.snapshot()["samples"][""]
    assert snap["count"] == len(values)
    assert sum(snap["buckets"].values()) == len(values)


def test_histogram_overflow_bucket_reports_floor():
    h = MetricsRegistry().histogram("huge")
    h.observe(float(2**100))
    assert h.percentile(99.0) == 2.0 ** (N_BUCKETS - 2)


# --------------------------------------------------------------------------
# tracing substrate
# --------------------------------------------------------------------------


def test_sampling_is_deterministic_and_calibrated():
    assert all(sampled(r, 1.0) for r in range(100))
    assert not any(sampled(r, 0.0) for r in range(100))
    hits = [sampled(r, 0.25) for r in range(4000)]
    assert hits == [sampled(r, 0.25) for r in range(4000)]  # run-stable
    assert 0.15 < np.mean(hits) < 0.35


def test_tracer_ring_and_sink_roundtrip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = Tracer(sample_rate=1.0, ring=4, sink=TraceSink(path))
    for rid in range(6):
        tr.begin(rid)
        t = tr.get(rid)
        t.span("queue", 0.0, 0.001, depth=rid)
        t.attrs["exit_reason"] = "safe"
        tr.end(rid)
    tr.close()
    assert len(tr.ring) == 4  # bounded window
    assert tr.started == 6 and tr.finished == 6
    recs = read_traces(path)
    assert [r["rid"] for r in recs] == list(range(6))  # sink keeps all
    assert recs[0]["spans"][0]["name"] == "queue"
    assert recs[0]["exit_reason"] == "safe"


def test_read_traces_skips_torn_tail_only(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    sink = TraceSink(path)
    tr = Tracer(sample_rate=1.0, sink=sink)
    for rid in range(3):
        tr.begin(rid)
        tr.end(rid)
    tr.close()
    with open(path, "a", encoding="utf-8") as f:
        f.write('{"rid": 3, "torn')  # crash mid-append
    assert [r["rid"] for r in read_traces(path)] == [0, 1, 2]
    # The next sink append repairs the tail before writing.
    tr2 = Tracer(sample_rate=1.0, sink=TraceSink(path))
    tr2.begin(7)
    tr2.end(7)
    tr2.close()
    assert [r["rid"] for r in read_traces(path)] == [0, 1, 2, 7]
    # Mid-file corruption is an error, not a silent skip.
    lines = open(path, encoding="utf-8").read().splitlines()
    lines.insert(1, "{broken")
    with open(path, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(ValueError):
        read_traces(path)


def test_fake_clock_is_shared_and_deterministic():
    clock = FakeClock(dt=0.5, start=10.0)
    assert clock() == 10.5
    assert clock() == 11.0
    clock.advance(4.0)
    assert clock() == 15.5


# --------------------------------------------------------------------------
# export + report
# --------------------------------------------------------------------------


def test_prometheus_exposition_shape():
    reg = MetricsRegistry()
    reg.counter("served", "queries served").inc(3, server="micro", reason="safe")
    reg.gauge("alpha").set(1.25)
    h = reg.histogram("latency_ms")
    for v in (0.5, 3.0, 70.0):
        h.observe(v, server="micro")
    text = prometheus_text(reg)
    assert '# TYPE served counter' in text
    assert 'served_total{reason="safe",server="micro"} 3' in text
    assert "alpha 1.25" in text
    assert '# TYPE latency_ms histogram' in text
    assert 'latency_ms_bucket{server="micro",le="+Inf"} 3' in text
    assert 'latency_ms_count{server="micro"} 3' in text
    # Cumulative buckets: every le line is monotone nondecreasing.
    les = [
        (float(ln.split('le="')[1].split('"')[0].replace("+Inf", "inf")),
         int(ln.rsplit(" ", 1)[1]))
        for ln in text.splitlines() if ln.startswith("latency_ms_bucket")
    ]
    assert les == sorted(les) and les[-1][1] == 3
    json.loads(json_snapshot(reg))  # exposition twin is valid JSON


def test_report_summary_math():
    recs = []
    for i in range(10):
        lat = 2.0 + i  # 2..11 ms
        recs.append({
            "rid": i,
            "exit_reason": "safe" if i % 2 == 0 else "budget",
            "latency_ms": lat,
            "sla_ms": 8.0,
            "quanta": 1 + i % 3,
            "fidelity_bound": 0 if i < 8 else 5,
            "exact": i < 8,
            "spans": [
                {"name": "queue", "t0_ms": 0.0, "dur_ms": 1.0},
                {"name": "service", "t0_ms": 1.0, "dur_ms": lat - 1.0},
            ],
        })
    s = summarize(recs, sla_ms=8.0)
    assert s["queries"] == 10
    assert s["sla"]["judged"] == 10
    assert s["sla"]["met"] == 7  # latencies 2..8 of 2..11
    assert s["sla"]["compliance"] == pytest.approx(0.7)
    assert s["exit_reasons"] == {"budget": 5, "safe": 5}
    assert s["queue_wait_ms"]["p50"] == pytest.approx(1.0)
    assert 0.0 < s["queue_share"] < 0.5
    assert s["fidelity_bound"]["nonzero"] == 2
    assert s["inexact"] == 2
    text = render(s)
    assert "compliance" in text and "exit reasons" in text


def test_report_cli(tmp_path, capsys):
    from repro.obs.__main__ import main

    path = str(tmp_path / "t.jsonl")
    tr = Tracer(sample_rate=1.0, sink=TraceSink(path))
    for rid in range(4):
        tr.begin(rid)
        t = tr.get(rid)
        t.span("queue", 0.0, 0.001)
        t.attrs.update(exit_reason="safe", latency_ms=3.0)
        tr.end(rid)
    tr.close()
    assert main(["report", path, "--sla-ms", "10"]) == 0
    out = capsys.readouterr().out
    assert "queries" in out and "4" in out
    assert main(["report", path, "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["queries"] == 4
    assert main(["report", str(tmp_path / "missing.jsonl")]) == 1


# --------------------------------------------------------------------------
# results neutrality: instrumentation changes nothing served
# --------------------------------------------------------------------------


def _served_observables(served):
    return [
        (
            s.rid,
            np.asarray(s.result.doc_ids).tolist(),
            np.asarray(s.result.scores).tolist(),
            result_exit_reason(s.result),
        )
        for s in sorted(served, key=lambda s: s.rid)
    ]


@pytest.mark.parametrize("mode", ["micro", "inflight"])
def test_instrumented_serving_is_bitwise_identical(tmp_path, mode):
    eng, queries = _small_setup(seed=3, n_ranges=6)
    budgets = [None, 800]  # unbounded and budget-exit paths both pinned

    def serve(obs, cap):
        beng = BatchEngine(eng, BucketSpec(max_batch=4))
        bud = SlaBudgeter(sla_ms=float("inf"), obs=obs)
        if cap is not None:
            bud.budgets = lambda n, plans=None: np.full(n, cap, np.int32)
        if mode == "micro":
            srv = MicroBatchServer(beng, bud, max_batch=4, obs=obs)
            for q in queries:
                srv.submit(q)
            served = []
            while srv.pending:
                served.extend(srv.drain_once())
            return served
        srv = InflightServer(beng, bud, n_slots=4, quantum=2, obs=obs)
        for q in queries:
            srv.submit(q)
        return srv.run_until_idle()

    for cap in budgets:
        path = str(tmp_path / f"{mode}-{cap}.jsonl")
        # profile=True: the dispatch profiler's extra sync points are
        # timing-only, so identity must hold with it attached too (§14).
        obs = Instrumentation.make(
            sample_rate=1.0, trace_path=path, profile=True
        )
        instrumented = serve(obs, cap)
        obs.close()
        baseline = serve(NOOP, cap)
        assert _served_observables(instrumented) == _served_observables(baseline)
        # Full-rate tracing saw every query exactly once.
        recs = read_traces(path)
        assert sorted(r["rid"] for r in recs) == sorted(
            s.rid for s in baseline
        )
        for r in recs:
            assert r["exit_reason"] in ("safe", "budget", "exhausted", "down")
            assert any(sp["name"] == "queue" for sp in r["spans"])
            assert any(
                sp["name"] in ("service", "dispatch") for sp in r["spans"]
            )


# --------------------------------------------------------------------------
# exit-reason conservation at every layer
# --------------------------------------------------------------------------


def test_engine_exit_reason_conservation():
    eng, queries = _small_setup(seed=5, n_ranges=6)
    obs = Instrumentation()
    eng_i = Engine(eng.index, k=5, obs=obs)
    reasons = []
    for i, q in enumerate(queries):
        plan = eng_i.plan(q)
        kw = {"budget_postings": 500} if i % 2 else {}
        res = eng_i.traverse(plan, **kw)
        reasons.append(exit_reason(bool(res.exit_safe), bool(res.exit_budget)))
    assert_exit_reason_conservation(obs, "engine_queries", reasons)
    assert obs.metrics.histogram("engine_postings").count() == len(queries)


def test_batch_engine_exit_reason_conservation():
    eng, queries = _small_setup(seed=6, n_ranges=6)
    obs = Instrumentation()
    beng = BatchEngine(Engine(eng.index, k=5), BucketSpec(max_batch=4), obs=obs)
    plans = beng.plan_many(queries)
    caps = [400 if i % 3 == 0 else None for i in range(len(plans))]
    results = beng.run_batch(
        plans, budget_postings=[c or 2**31 - 1 for c in caps]
    )
    assert_exit_reason_conservation(
        obs, "batch_engine_queries", [r.exit_reason for r in results]
    )


@pytest.mark.parametrize("mode", ["micro", "inflight"])
def test_server_exit_reason_conservation(mode):
    eng, queries = _small_setup(seed=7, n_ranges=6)
    obs = Instrumentation.make(sample_rate=1.0)
    beng = BatchEngine(eng, BucketSpec(max_batch=4))
    bud = SlaBudgeter(sla_ms=float("inf"), obs=obs)
    if mode == "micro":
        srv = MicroBatchServer(beng, bud, max_batch=4, obs=obs)
        for q in queries:
            srv.submit(q)
        served = []
        while srv.pending:
            served.extend(srv.drain_once())
        label = "micro"
    else:
        srv = InflightServer(beng, bud, n_slots=4, quantum=2, obs=obs)
        for q in queries:
            srv.submit(q)
        served = srv.run_until_idle()
        label = "inflight"
    assert_exit_reason_conservation(
        obs,
        "served_queries",
        [result_exit_reason(s.result) for s in served],
        server=label,
    )
    sub = obs.metrics.counter("submitted")
    assert sub.value(server=label) == len(queries)


def test_sharded_exit_reason_conservation_per_shard():
    eng, queries = _small_setup(seed=8, n_ranges=8)
    obs = Instrumentation()
    se = ShardedEngine(
        Engine(eng.index, k=5), n_shards=2, use_mesh=False, obs=obs
    )
    per_shard: dict[int, list[str]] = {0: [], 1: []}
    merged = []
    for q in queries:
        r = se.traverse(se.engine.plan(q))
        for s, reason in enumerate(r.shard_exit_reasons):
            per_shard[s].append(reason)
        merged.append(result_exit_reason(r))
    for s, reasons in per_shard.items():
        assert_exit_reason_conservation(
            obs, "shard_exits", reasons, context=f"shard {s}", shard=s
        )
    # The merged counter sums to queries served, one count per query.
    total = obs.metrics.counter("sharded_queries").total()
    assert total == len(queries) == len(merged)
    assert obs.metrics.histogram("fidelity_bound").count() == len(queries)


# --------------------------------------------------------------------------
# control plane instrumentation
# --------------------------------------------------------------------------


def test_control_plane_health_and_serving_telemetry():
    from repro.control import ControlPlane

    eng, queries = _small_setup(seed=9, n_ranges=8)
    obs = Instrumentation.make(sample_rate=1.0)
    plane = ControlPlane(eng, n_shards=2, use_mesh=False, obs=obs)
    served = plane.replay(queries, batch_size=4)
    assert len(served) == len(queries)
    plane.mark_down(1)
    down = plane.replay(queries[:4], batch_size=4)
    plane.mark_up(1)
    assert len(down) == 4
    ht = obs.metrics.counter("health_transitions")
    assert ht.value(event="down", shard=1) == 1
    assert ht.value(event="up", shard=1) == 1
    assert_exit_reason_conservation(
        obs,
        "served_queries",
        [result_exit_reason(s.result) for s in served + down],
        server="micro",
    )
    # Down-shard serving surfaced inexactness in the fidelity telemetry.
    assert obs.metrics.counter("sharded_exact").value(exact=False) >= 1


# --------------------------------------------------------------------------
# ISSUE 9 / DESIGN.md §14: help catalog, profiler, SLOs, detectors, ops loop
# --------------------------------------------------------------------------


def test_every_registered_metric_carries_help():
    """Drive every serving layer through one handle: no empty help strings."""
    from repro.control import ControlPlane
    from repro.obs.slo import SloTracker, default_serving_slos

    eng, queries = _small_setup(seed=10, n_ranges=8)
    obs = Instrumentation.make(sample_rate=1.0, profile=True)
    plane = ControlPlane(eng, n_shards=2, use_mesh=False, obs=obs)
    plane.replay(queries[:8], batch_size=4)
    srv = InflightServer(
        BatchEngine(eng, BucketSpec(max_batch=4)),
        SlaBudgeter(sla_ms=float("inf"), obs=obs),
        n_slots=4,
        obs=obs,
    )
    for q in queries[:4]:
        srv.submit(q)
    srv.run_until_idle()
    tracker = SloTracker(obs, default_serving_slos(sla_ms=5.0))
    tracker.sample(now=0.0)
    tracker.evaluate(now=1.0)
    assert obs.metrics.missing_help() == []
    text = prometheus_text(obs.metrics)
    assert "# HELP latency_ms" in text
    assert "# HELP served_queries" in text
    assert "# HELP profiler_dispatches" in text


def test_unlimited_budget_sentinel_stays_out_of_histogram():
    """INT32_MAX admissions count separately; finite budgets histogram."""
    eng, queries = _small_setup(seed=13, n_ranges=6)

    obs = Instrumentation.make(sample_rate=1.0)
    srv = InflightServer(
        BatchEngine(eng, BucketSpec(max_batch=4)),
        SlaBudgeter(sla_ms=float("inf"), obs=obs),
        n_slots=4,
        obs=obs,
    )
    for q in queries:
        srv.submit(q)
    srv.run_until_idle()
    assert obs.metrics.histogram("budget_postings").count(server="inflight") == 0
    unl = obs.metrics.counter("unlimited_admissions").value(server="inflight")
    adm = obs.metrics.counter("admissions").value(server="inflight")
    assert unl == adm == len(queries)

    obs2 = Instrumentation.make(sample_rate=1.0)
    bud = SlaBudgeter(sla_ms=float("inf"), obs=obs2)
    bud.budgets = lambda n, plans=None: np.full(n, 800, np.int32)
    srv2 = MicroBatchServer(
        BatchEngine(eng, BucketSpec(max_batch=4)), bud, max_batch=4, obs=obs2
    )
    for q in queries:
        srv2.submit(q)
    while srv2.pending:
        srv2.drain_once()
    h2 = obs2.metrics.histogram("budget_postings")
    assert h2.count(server="micro") == len(queries)
    assert h2.percentile(50.0, server="micro") <= 1024.0  # real budgets, not 2^31
    assert obs2.metrics.counter("unlimited_admissions").value(server="micro") == 0


def test_cdf_below_bucket_edges():
    from repro.obs.slo import cdf_below

    buckets = [0] * N_BUCKETS
    buckets[bucket_index(5.0)] = 8  # [4, 8)
    assert cdf_below(buckets, 8.0) == pytest.approx(8.0)  # edge is exact
    assert cdf_below(buckets, 4.0) == pytest.approx(0.0)
    assert cdf_below(buckets, 6.0) == pytest.approx(4.0)  # interpolated
    assert cdf_below(buckets, -1.0) == 0.0
    over = [0] * N_BUCKETS
    over[N_BUCKETS - 1] = 3  # overflow bucket
    assert cdf_below(over, 1e18) == 0.0  # no interpolable mass
    assert cdf_below(over, float("inf")) == pytest.approx(3.0)


def test_slo_tracker_windowed_burn_hand_computed():
    """Two windows, hand-placed events: burn = (1 - a) / (1 - objective)."""
    from repro.obs.slo import HistogramBelow, SloSpec, SloTracker

    obs = Instrumentation()
    tracker = SloTracker(
        obs,
        [SloSpec("lat", 0.9, HistogramBelow("latency_ms", 8.0))],
        windows={"10s": 10.0, "100s": 100.0},
    )
    tracker.sample(now=0.0)
    for _ in range(6):
        obs.observe("latency_ms", 3.0)  # good: whole bucket under 8.0
    tracker.sample(now=50.0)
    for _ in range(4):
        obs.observe("latency_ms", 100.0)  # bad
    tracker.sample(now=100.0)
    rep = tracker.evaluate(now=100.0)["lat"]
    w10, w100 = rep["windows"]["10s"], rep["windows"]["100s"]
    # 10s window sees only the 4 bad events: attainment 0, burn 1/0.1.
    assert w10["total"] == pytest.approx(4.0)
    assert w10["attainment"] == pytest.approx(0.0)
    assert w10["burn"] == pytest.approx(10.0)
    # 100s window sees all 10: attainment 0.6, burn 0.4/0.1.
    assert w100["total"] == pytest.approx(10.0)
    assert w100["attainment"] == pytest.approx(0.6)
    assert w100["burn"] == pytest.approx(4.0)
    assert rep["budget_remaining"] == 0.0  # long burn 4.0 >= 1
    # Evaluate mirrored the report into slo_* gauges.
    g = obs.metrics.gauge("slo_burn_rate")
    assert g.value(slo="lat", window="10s") == pytest.approx(10.0)
    assert obs.metrics.gauge("slo_state").value(slo="lat") == 0  # ok


def test_ewma_detector_fire_clear_hysteresis():
    from repro.obs.detect import EwmaDetector

    det = EwmaDetector(
        "sig", patience=3, clear_patience=2, min_samples=4, direction="above"
    )
    clock = FakeClock(dt=1.0)
    for _ in range(6):  # warm-up + settled baseline
        assert det.update(10.0, clock()) is None
    assert det.mean == pytest.approx(10.0)
    got = [det.update(100.0, clock()) for _ in range(3)]
    assert got[0] is None and got[1] is None  # patience absorbs two spikes
    assert got[2] is not None and got[2].state == "fire"
    assert det.firing
    assert det.mean == pytest.approx(10.0)  # baseline frozen, not chasing
    back = [det.update(10.0, clock()) for _ in range(2)]
    assert back[0] is None
    assert back[1] is not None and back[1].state == "clear"
    assert not det.firing
    # A lone spike after clearing neither fires nor shifts the baseline much.
    assert det.update(100.0, clock()) is None
    assert det.mean < 20.0


def test_threshold_detector_and_monitor_emit_to_sink(tmp_path):
    from repro.obs.detect import DriftMonitor, ThresholdDetector

    path = str(tmp_path / "t.jsonl")
    obs = Instrumentation.make(
        sample_rate=1.0, trace_path=path, clock=FakeClock(dt=1.0)
    )
    mon = DriftMonitor(obs)
    sig = {"v": 0.5}
    mon.add(
        ThresholdDetector("skew", 2.0, patience=2, clear_patience=1),
        lambda reg: sig["v"],
    )
    seen = []
    mon.subscribe(lambda ev: seen.append((ev.detector, ev.state)))
    assert mon.poll() == []
    sig["v"] = 3.0
    assert mon.poll() == []  # patience
    fired = mon.poll()
    assert [e.state for e in fired] == ["fire"]
    assert mon.firing() == ["skew"]
    sig["v"] = 1.0
    assert [e.state for e in mon.poll()] == ["clear"]
    assert seen == [("skew", "fire"), ("skew", "clear")]
    assert obs.metrics.counter("alerts").value(detector="skew", state="fire") == 1
    obs.close()
    recs = read_traces(path)
    alerts = [r for r in recs if r.get("kind") == "alert"]
    assert [a["state"] for a in alerts] == ["fire", "clear"]
    # Alert records do not pollute the query-report math.
    assert summarize(recs)["queries"] == 0
    assert summarize(recs)["alerts"] == 2


def test_profiler_compile_recompile_classification():
    from repro.obs.profiler import Profiler

    obs = Instrumentation()
    prof = Profiler(obs)
    prof.record_dispatch("s", (4, 32), cache_before=0, cache_after=1)  # compile
    prof.record_dispatch("s", (4, 32), cache_before=1, cache_after=1)  # warm hit
    prof.record_dispatch("s", (4, 32), cache_before=1, cache_after=2)  # RECOMPILE
    prof.record_dispatch("s", (8, 32), cache_before=2, cache_after=3)  # compile
    prof.record_dispatch("s", (8, 64))  # no introspection: novelty fallback
    prof.record_dispatch("s", (8, 64))  # seen + no introspection: nothing
    snap = prof.snapshot()["s"]
    assert snap["dispatches"] == 6
    assert snap["compiles"] == 3
    assert snap["recompiles"] == 1
    assert prof.recompiles() == 1
    c = obs.metrics.counter("profiler_recompiles")
    assert c.value(site="s") == 1


def test_profiler_tracks_bucket_ladder_without_recompiles():
    """Across the pow2 ladder: one compile per program, zero recompiles."""
    # k=7 is unique to this test, so the module-level jit cache has no
    # warm entries for these programs and every first-seen shape compiles.
    eng, queries = _small_setup(seed=12, n_ranges=6, k=7)
    obs = Instrumentation.make(sample_rate=1.0, profile=True)
    beng = BatchEngine(eng, BucketSpec(max_batch=4), obs=obs)
    plans = beng.plan_many(queries)
    for chunk in (plans[:1], plans[:3], plans):  # batch buckets 1, 4, 4x3
        beng.run_batch(chunk)
    snap = obs.profiler.snapshot()["batch_engine"]
    assert snap["recompiles"] == 0
    assert snap["dispatches"] == beng.batches_run
    assert {tuple(s) for s in snap["shapes"]} == beng.compiled_shapes
    assert snap["compiles"] == len(beng.compiled_shapes)
    assert snap["device_ms"] > 0.0
    assert snap["hbm_total_bytes"] > 0
    # A second pass over warm programs adds dispatches, never compiles.
    beng.run_batch(plans)
    snap2 = obs.profiler.snapshot()["batch_engine"]
    assert snap2["dispatches"] > snap["dispatches"]
    assert snap2["compiles"] == snap["compiles"]
    assert snap2["recompiles"] == 0


def test_planted_shard_skew_arms_reshard_via_alert(tmp_path):
    """Detector -> ControlPlane arming, end-to-end through real serving."""
    from repro.control import ControlPlane
    from repro.obs.detect import DriftMonitor, ShardSkewProbe, ThresholdDetector

    eng, queries = _small_setup(seed=11, n_ranges=8, n_queries=24)
    path = str(tmp_path / "trace.jsonl")
    obs = Instrumentation.make(sample_rate=1.0, trace_path=path)
    plane = ControlPlane(
        eng, n_shards=2, use_mesh=False, obs=obs, reshard_trigger=1.02
    )
    # Plant the skew: dry-run the log on an uninstrumented twin engine,
    # then replay the single most shard-skewed query so one shard eats
    # the workload on every consecutive drain.
    twin = ShardedEngine(Engine(eng.index, k=5), n_shards=2, use_mesh=False)
    ratio = []
    for q in queries:
        p = np.asarray(
            twin.traverse(twin.engine.plan(q)).shard_postings, np.float64
        )
        ratio.append(p.max() * 2.0 / max(p.sum(), 1.0))
    hot = queries[int(np.argmax(ratio))]
    assert max(ratio) >= 1.5  # the plant is a real, strong skew

    monitor = DriftMonitor(obs)
    monitor.add(
        ThresholdDetector("shard_skew", 1.3, patience=2), ShardSkewProbe(2)
    )
    plane.enable_operations(monitor=monitor)
    for _ in range(16):
        plane.submit(hot)
        plane.drain_once()
    while plane.pending or plane.reshard_task is not None:
        plane.drain_once()

    fires = obs.metrics.counter("alerts").value(
        detector="shard_skew", state="fire"
    )
    assert fires >= 1
    # The sustained alert armed the planner's reshard path.
    assert plane.reshards_completed >= 1
    assert obs.metrics.counter("reshard_started").total() >= 1
    obs.close()
    alerts = [r for r in read_traces(path) if r.get("kind") == "alert"]
    assert any(
        a["detector"] == "shard_skew" and a["state"] == "fire" for a in alerts
    )


def test_burn_rate_alert_marks_plane_degraded():
    """Impossible latency SLO -> fast burn -> degraded-SLO plane state."""
    from repro.control import ControlPlane
    from repro.obs.detect import DriftMonitor, ThresholdDetector, gauge_probe
    from repro.obs.slo import SloTracker, default_serving_slos

    eng, queries = _small_setup(seed=14, n_ranges=8)
    obs = Instrumentation.make(sample_rate=1.0)
    plane = ControlPlane(eng, n_shards=2, use_mesh=False, obs=obs)
    tracker = SloTracker(obs, default_serving_slos(sla_ms=1e-4))
    monitor = DriftMonitor(obs)
    monitor.add(
        ThresholdDetector("slo_fast_burn", 14.4, patience=2),
        gauge_probe("slo_burn_rate", slo="latency_sla", window="5m"),
    )
    plane.enable_operations(slos=tracker, monitor=monitor)
    plane.replay(queries, batch_size=4)
    assert plane.stats()["degraded_slo"] is True
    assert "slo_fast_burn" in monitor.firing()
    assert obs.metrics.gauge("plane_degraded_slo").value() == 1.0
    assert obs.metrics.gauge("slo_state").value(slo="latency_sla") == 2


def test_slo_cli(tmp_path, capsys):
    from repro.obs.__main__ import main

    path = str(tmp_path / "t.jsonl")
    tr = Tracer(sample_rate=1.0, sink=TraceSink(path))
    clock = FakeClock(dt=0.01)
    for rid in range(8):
        tr.begin(rid)
        t = tr.get(rid)
        t0 = clock()
        lat = 2.0 if rid < 6 else 50.0
        t.span("service", t0, t0 + lat / 1e3)
        t.attrs.update(
            exit_reason="safe", latency_ms=lat, sla_ms=10.0, exact=True
        )
        tr.end(rid)
    tr.close()
    assert main(["slo", path]) == 0
    out = capsys.readouterr().out
    assert "latency_sla" in out and "burn" in out
    assert main(["slo", path, "--json", "--windows", "w=1"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["queries"] == 8
    assert rep["sla_ms"] == 10.0  # recovered from the recorded attribute
    assert rep["slos"]["latency_sla"]["attainment"] == pytest.approx(0.75)
    assert main(["slo", str(tmp_path / "missing.jsonl")]) == 1


def test_watch_cli(tmp_path, capsys):
    from repro.obs import write_snapshot
    from repro.obs.__main__ import main

    obs = Instrumentation.make(sample_rate=1.0)
    obs.count("served_queries", 5, server="micro", reason="safe")
    obs.observe("latency_ms", 3.0, server="micro")
    obs.gauge("queue_depth", 2.0, server="micro")
    snap = str(tmp_path / "snap.json")
    write_snapshot(
        snap,
        obs.metrics,
        alerts=[{"detector": "skew", "state": "fire", "value": 2.5, "t_ms": 1.0}],
        t=12.5,
    )
    assert main(["watch", snap, "--once"]) == 0
    out = capsys.readouterr().out
    assert "served_queries" in out and "latency_ms" in out
    assert "skew" in out  # the alert tail rendered
    assert main(["watch", str(tmp_path / "missing.json"), "--once"]) == 1
