"""Bit-packed docid delta codec: round-trip properties + space accounting.

The codec contract (DESIGN.md §12): ``unpack_docs(pack_docs(x, s, l), s, l)
== x`` bitwise for *any* valid block geometry, with the width directory
always choosing the smallest of ``PACK_WIDTHS`` that covers a block's max
delta. Property tests sweep randomized geometries; targeted cases pin the
edges the sweep can miss — 0-bit constant runs, single-posting blocks,
short tails, and full 32-bit deltas. Space assertions tie the accounting
formula to the actual uploaded device buffers.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.clustered_index import (
    BLOCK,
    PACK_DIR_BITS,
    PACK_WIDTHS,
    build_index,
    device_bytes_report,
    pack_dir_entries,
    pack_docs,
    unpack_docs,
)
from repro.core.range_daat import Engine
from repro.data.synth import make_corpus


def _random_geometry(rng, n_blocks, max_delta, max_len=BLOCK):
    """Random block-contiguous docid stream with bounded deltas."""
    blk_len = rng.integers(1, max_len + 1, size=n_blocks).astype(np.int64)
    blk_start = np.cumsum(blk_len) - blk_len
    chunks = []
    for length in blk_len:
        deltas = rng.integers(0, max_delta + 1, size=int(length))
        deltas[0] = 0  # block head carries the absolute docid
        chunks.append(int(rng.integers(0, 10_000)) + np.cumsum(deltas))
    return np.concatenate(chunks).astype(np.int64), blk_start, blk_len


# ------------------------------------------------------------ property sweep


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**30),
    max_delta=st.sampled_from([0, 1, 200, 255, 256, 65_535, 65_536, 2**20]),
    n_blocks=st.sampled_from([1, 3, 17]),
)
def test_pack_unpack_round_trip(seed, max_delta, n_blocks):
    rng = np.random.default_rng(seed)
    docs, blk_start, blk_len = _random_geometry(rng, n_blocks, max_delta)
    packed = pack_docs(docs, blk_start, blk_len)
    assert packed.n_postings == docs.shape[0]
    assert set(np.unique(packed.blk_width)) <= set(PACK_WIDTHS)
    # Width minimality: the directory picks the smallest covering width.
    for b in range(n_blocks):
        s, length = int(blk_start[b]), int(blk_len[b])
        d = np.diff(docs[s : s + length], prepend=docs[s]).max(initial=0)
        expect = next(w for w in PACK_WIDTHS if d < (1 << w) or w == 32)
        assert int(packed.blk_width[b]) == expect, (b, d)
    # Exact word budget: ceil(len * width / 32) per block, densely laid out.
    wpb = (blk_len * packed.blk_width + 31) // 32
    assert packed.n_words == int(wpb.sum())
    np.testing.assert_array_equal(
        packed.blk_word_start, np.cumsum(wpb) - wpb
    )
    np.testing.assert_array_equal(
        unpack_docs(packed, blk_start, blk_len), docs
    )


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**30), n_blocks=st.sampled_from([1, 9]))
def test_constant_and_singleton_blocks_cost_zero_words(seed, n_blocks):
    """0-bit runs: constant blocks and 1-posting blocks store no words."""
    rng = np.random.default_rng(seed)
    docs, blk_start, blk_len = _random_geometry(rng, n_blocks, max_delta=0)
    packed = pack_docs(docs, blk_start, blk_len)
    assert packed.n_words == 0
    assert np.all(packed.blk_width == 0)
    np.testing.assert_array_equal(packed.blk_first, docs[blk_start])
    np.testing.assert_array_equal(unpack_docs(packed, blk_start, blk_len), docs)

    ones = np.ones(n_blocks, np.int64)  # every block a single posting
    singles = np.arange(n_blocks, dtype=np.int64) * 37
    p1 = pack_docs(singles, np.arange(n_blocks, dtype=np.int64), ones)
    assert p1.n_words == 0 and np.all(p1.blk_width == 0)
    np.testing.assert_array_equal(
        unpack_docs(p1, np.arange(n_blocks, dtype=np.int64), ones), singles
    )


def test_short_tails_and_full_width_edges():
    """Tail blocks (< BLOCK lanes) and the 32-bit max-delta extreme."""
    # Mixed lengths incl. length-1 and length-BLOCK, forced width ladder.
    blk_len = np.asarray([1, 5, BLOCK, 3, 2], np.int64)
    blk_start = np.cumsum(blk_len) - blk_len
    rng = np.random.default_rng(0)
    docs = np.concatenate(
        [
            [7],
            5 + np.cumsum([0, 1, 1, 0, 1]),  # width 4
            np.cumsum(np.r_[0, rng.integers(0, 300, BLOCK - 1)]),  # width 16
            10 + np.cumsum([0, 70_000, 70_000]),  # width 32
            [4, 4],  # width 0
        ]
    ).astype(np.int64)
    packed = pack_docs(docs, blk_start, blk_len)
    assert packed.blk_width.tolist() == [0, 4, 16, 32, 0]
    np.testing.assert_array_equal(unpack_docs(packed, blk_start, blk_len), docs)

    # Max int32-representable delta: the 32-bit lane mask must not
    # truncate or sign-extend (docids themselves stay int32).
    big = np.asarray([1, 1 + (2**31 - 2)], np.int64)
    pb = pack_docs(big, np.asarray([0], np.int64), np.asarray([2], np.int64))
    assert pb.blk_width.tolist() == [32]
    np.testing.assert_array_equal(
        unpack_docs(pb, np.asarray([0], np.int64), np.asarray([2], np.int64)),
        big,
    )


def test_merged_directory_entries_round_trip():
    """pack_dir_entries ⊕ unpack_dir recovers (word_start, width) exactly."""
    import dataclasses

    import jax.numpy as jnp

    from repro.kernels.range_scorer.ref import unpack_dir

    rng = np.random.default_rng(7)
    docs, blk_start, blk_len = _random_geometry(rng, 17, max_delta=2**20)
    packed = pack_docs(docs, blk_start, blk_len)
    entries = pack_dir_entries(packed)
    assert entries.dtype == np.int32 and np.all(entries >= 0)
    ws, w = unpack_dir(jnp.asarray(entries))
    np.testing.assert_array_equal(np.asarray(ws), packed.blk_word_start)
    np.testing.assert_array_equal(np.asarray(w), packed.blk_width)

    # Word offsets beyond the 2^PACK_DIR_BITS cap must refuse to merge, not
    # silently corrupt the width bits (zero-strided view: no allocation).
    huge = dataclasses.replace(
        packed,
        words=np.broadcast_to(np.zeros(1, np.uint32), (1 << PACK_DIR_BITS,)),
    )
    with pytest.raises(ValueError, match="shard the index"):
        pack_dir_entries(huge)


def test_pack_rejects_invalid_input():
    s1 = np.asarray([0], np.int64)
    with pytest.raises(ValueError, match="BLOCK"):
        pack_docs(
            np.arange(BLOCK + 1), s1, np.asarray([BLOCK + 1], np.int64)
        )
    with pytest.raises(ValueError, match="non-negative"):
        pack_docs(np.asarray([-1, 2]), s1, np.asarray([2], np.int64))
    with pytest.raises(ValueError, match="non-decreasing"):
        pack_docs(np.asarray([5, 3]), s1, np.asarray([2], np.int64))


# ------------------------------------------------------- built-index mirror


def test_built_index_round_trip_and_cache():
    corpus = make_corpus(
        n_docs=400, n_terms=300, n_topics=4, mean_doc_len=40, seed=2
    )
    idx = build_index(corpus, n_ranges=4, strategy="clustered")
    packed = idx.packed_postings()
    assert packed is idx.packed_postings()  # cached per index object
    np.testing.assert_array_equal(
        unpack_docs(packed, idx.blk_start, idx.blk_len), idx.docs
    )
    # The packed mirror is strictly smaller than raw int32 docids here.
    assert packed.device_nbytes() < idx.nnz * 4


def test_space_report_matches_uploaded_buffers():
    """The accounting formula equals the actual device buffer nbytes."""
    corpus = make_corpus(
        n_docs=400, n_terms=300, n_topics=4, mean_doc_len=40, seed=3
    )
    idx = build_index(corpus, n_ranges=4, strategy="clustered")
    for docs_format, impact_dtype in [
        ("int32", "int32"), ("packed", "int8"), ("packed", "int32")
    ]:
        eng = Engine(
            idx, k=5, impact_dtype=impact_dtype, docs_format=docs_format
        )
        dev = idx.device_bytes(impact_dtype, docs_format)
        if docs_format == "packed":
            uploaded = (
                eng.dix.pack_words.nbytes
                + eng.dix.pack_dir.nbytes
                + eng.dix.pack_first.nbytes
            )
            # The 4-byte docs placeholder is jit plumbing, not postings.
            assert eng.dix.docs.nbytes == 4
        else:
            uploaded = eng.dix.docs.nbytes
            assert eng.dix.pack_words is None
        assert dev["docs"] == uploaded, (docs_format, impact_dtype)
        assert dev["impacts"] == eng.dix.impacts.nbytes
        assert dev["postings"] == dev["docs"] + dev["impacts"]
        # Formula-only path (manifest metadata) agrees with the index path.
        meta = device_bytes_report(
            nnz=idx.nnz,
            n_blocks=idx.n_blocks,
            n_terms=idx.n_terms,
            n_ranges=idx.n_ranges,
            impact_dtype=impact_dtype,
            docs_format=docs_format,
            n_pack_words=idx.packed_postings().n_words,
        )
        assert meta == dev
    assert jax.device_count() >= 1  # sanity: buffers actually uploaded


def test_space_report_surfaces_packed_device_bytes():
    corpus = make_corpus(
        n_docs=300, n_terms=200, n_topics=3, mean_doc_len=30, seed=5
    )
    idx = build_index(corpus, n_ranges=3, strategy="clustered")
    rep_raw = idx.space_report("int8", "int32")
    rep_pk = idx.space_report("int8", "packed")
    assert rep_pk["device_bytes"]["docs"] < rep_raw["device_bytes"]["docs"]
    # Logical paper-width accounting is format-independent.
    assert rep_pk["postings_gib"] == rep_raw["postings_gib"]
