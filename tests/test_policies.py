"""Unit tests for the §6 termination policies (Eqs. 3-7)."""

from __future__ import annotations

import numpy as np

from repro.core.anytime import Fixed, Overshoot, Predictive, Reactive, Undershoot


def test_overshoot_eq3():
    p = Overshoot()
    assert p.decide(49.9, 3, 50.0)
    assert not p.decide(50.0, 3, 50.0)


def test_undershoot_eq4():
    p = Undershoot(t_max_ms=5.0)
    assert p.decide(44.9, 3, 50.0)
    assert not p.decide(45.0, 3, 50.0)  # 45 + 5 = 50, not < 50


def test_predictive_eq5():
    p = Predictive(alpha=1.0)
    # mean range time = 10ms over 2 ranges -> continue iff 20 + 10 < B
    assert p.decide(20.0, 2, 31.0)
    assert not p.decide(20.0, 2, 30.0)
    assert p.decide(0.0, 0, 1.0)  # first range always admitted
    p2 = Predictive(alpha=2.0)
    assert not p2.decide(20.0, 2, 40.0)  # 20 + 2*10 = 40, not < 40
    assert p2.decide(20.0, 2, 41.0)


def test_reactive_eq7_miss_grows_alpha():
    p = Reactive(alpha=1.0, beta=1.5, q=0.01)
    p.on_query_end(60.0, 50.0)  # miss
    assert np.isclose(p.alpha, 1.5)


def test_reactive_eq7_hundred_hits_shrink_two_thirds():
    """Paper §6.4: with beta=1.5, 100 within-limit queries scale alpha by 2/3."""
    p = Reactive(alpha=1.0, beta=1.5, q=0.01)
    for _ in range(100):
        p.on_query_end(10.0, 50.0)
    assert np.isclose(p.alpha, 2.0 / 3.0, rtol=1e-6)


def test_reactive_bounded():
    p = Reactive(alpha=1.0, beta=2.0, q=0.01, alpha_max=4.0)
    for _ in range(10):
        p.on_query_end(100.0, 1.0)
    assert p.alpha <= 4.0


def test_fixed_policy():
    p = Fixed(5)
    assert p.decide(1e9, 4, 0.0)
    assert not p.decide(0.0, 5, 1e9)
    assert p.name == "Fixed-5"


def test_reactive_clamps_at_alpha_min():
    p = Reactive(alpha=0.2, beta=2.0, q=1.0, alpha_min=0.1, alpha_max=64.0)
    for _ in range(20):
        p.on_query_end(1.0, 100.0)  # easy hits drive alpha down...
    assert np.isclose(p.alpha, p.alpha_min)  # ...onto the floor, not past it
    p.on_query_end(1.0, 100.0)
    assert p.alpha >= p.alpha_min


def test_reactive_clamps_at_alpha_max():
    p = Reactive(alpha=32.0, beta=2.0, q=0.01, alpha_min=0.1, alpha_max=64.0)
    for _ in range(10):
        p.on_query_end(200.0, 50.0)  # misses drive alpha up
    assert np.isclose(p.alpha, p.alpha_max)
    assert all(a <= p.alpha_max for a in p.trace)


def test_undershoot_never_exceeds_budget():
    """Eq. 4 invariant: a query governed by Undershoot finishes within
    budget for ANY range-time sequence bounded by t_max — simulated
    independently of decide()'s formula."""
    t_max = 7.0
    rng = np.random.default_rng(0)
    for trial in range(200):
        p = Undershoot(t_max_ms=t_max)
        budget = float(rng.uniform(1.0, 100.0))
        t = 0.0
        for i in range(100):
            if not p.decide(t, i, budget):
                break
            # Adversarial worst case: the admitted range takes exactly t_max.
            t += t_max
        assert t <= budget, (trial, t, budget)  # never violates the SLA
    assert not p.decide(10.0, 3, 10.0 + t_max)  # boundary: not strict-less


def test_fixed_n_processes_exactly_min_n_R(engine, queries):
    from repro.core.anytime import run_query_anytime

    R = engine.index.n_ranges
    for n in (0, 2, R, R + 5):
        plan = engine.plan(queries[0])
        res = run_query_anytime(
            engine, plan, policy=Fixed(n), budget_ms=1e9, safe_stop=False
        )
        assert res.ranges_processed == min(n, R)
        assert res.exit_reason == ("policy" if n < R else "exhausted")
