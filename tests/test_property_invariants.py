"""Hypothesis property tests on the system's core invariants.

Random corpora/queries at small scale; each property is one the engine's
correctness rests on:
  * BoundSum admissibility: sum_t U[t,r] upper-bounds every document score
    inside range r (the safe-termination proof's premise);
  * end-to-end rank safety: the safe traversal equals the oracle on
    arbitrary corpora, not just the shared fixtures;
  * quantization order preservation (up to quantization ties).
"""

from __future__ import annotations

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.clustered_index import build_index
from repro.core.oracle import exhaustive_scores, exhaustive_topk
from repro.core.quantize import fit_quantizer
from repro.core.range_daat import Engine
from repro.data.synth import make_corpus, make_query_log


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**30), n_ranges=st.sampled_from([2, 4, 7]))
def test_boundsum_is_admissible(seed, n_ranges):
    corpus = make_corpus(n_docs=300, n_terms=300, n_topics=4,
                         mean_doc_len=40, seed=seed % 1000)
    idx = build_index(corpus, n_ranges=n_ranges, strategy="clustered")
    ql = make_query_log(corpus, n_queries=4, seed=seed % 997)
    range_of = np.searchsorted(idx.range_ends, np.arange(idx.n_docs), "right")
    for i in range(ql.n_queries):
        q = [int(t) for t in ql.terms[i] if t >= 0]
        scores = exhaustive_scores(idx, np.asarray(q))
        bsum = idx.bounds_dense[q].sum(axis=0)
        for r in range(idx.n_ranges):
            m = range_of == r
            if m.any():
                assert scores[m].max() <= bsum[r], (r, scores[m].max(), bsum[r])


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_safe_traversal_safe_on_random_corpora(seed):
    corpus = make_corpus(n_docs=250, n_terms=250, n_topics=3,
                         mean_doc_len=30, seed=seed % 1000)
    idx = build_index(corpus, n_ranges=3, strategy="clustered_random")
    eng = Engine(idx, k=5)
    ql = make_query_log(corpus, n_queries=3, seed=seed % 991)
    for i in range(ql.n_queries):
        res = eng.traverse(eng.plan(ql.terms[i]))
        ids, vals = eng.topk_docs(res.state)
        oid, osc = exhaustive_topk(idx, ql.terms[i], 5)
        assert ids.tolist() == oid.tolist()
        assert vals.tolist() == osc.tolist()


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bits=st.sampled_from([4, 6, 8, 10]),
)
def test_quantizer_preserves_order_up_to_ties(seed, bits):
    rng = np.random.default_rng(seed)
    scores = rng.uniform(0.01, 10.0, size=64).astype(np.float32)
    q = fit_quantizer(scores, bits=bits)
    imp = q.quantize(scores)
    order = np.argsort(scores)
    assert np.all(np.diff(imp[order]) >= 0)  # monotone in the float order
    # Round trip is within one quantization step.
    back = q.dequantize(imp)
    assert np.all(np.abs(back - scores) <= 1.0 / q.scale + 1e-6)
