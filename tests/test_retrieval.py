"""Anytime MIPS retrieval (the paper's technique on dense candidates)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.retrieval import anytime_mips, build_clustered_candidates


@pytest.fixture(scope="module")
def candidates():
    rng = np.random.default_rng(0)
    # Clusterable embeddings: 8 planted directions + noise.
    centers = rng.normal(0, 1, size=(8, 32)).astype(np.float32)
    assign = rng.integers(0, 8, size=5000)
    emb = centers[assign] + 0.3 * rng.normal(0, 1, size=(5000, 32)).astype(np.float32)
    return emb.astype(np.float32)


@pytest.fixture(scope="module")
def cc(candidates):
    return build_clustered_candidates(candidates, n_clusters=16, seed=1)


def _brute_topk(emb, q, k):
    scores = emb @ np.asarray(q).T if np.asarray(q).ndim == 2 else emb @ np.asarray(q)
    if scores.ndim == 2:
        scores = scores.max(1)
    order = np.lexsort((np.arange(len(scores)), -scores))[:k]
    return order, scores[order]


def test_safe_mips_matches_brute_force(candidates, cc):
    rng = np.random.default_rng(2)
    for i in range(8):
        q = rng.normal(0, 1, size=32).astype(np.float32)
        res = anytime_mips(cc, jnp.asarray(q), k=10)
        oid, osc = _brute_topk(candidates, q, 10)
        np.testing.assert_allclose(
            np.sort(np.asarray(res.scores)), np.sort(osc), rtol=1e-5
        )
        assert set(np.asarray(res.ids).tolist()) == set(oid.tolist())


def test_safe_exit_prunes_clusters(candidates, cc):
    """Queries aligned with a planted direction should stop early."""
    rng = np.random.default_rng(3)
    processed = []
    for _ in range(8):
        q = candidates[rng.integers(0, len(candidates))]  # in-distribution
        res = anytime_mips(cc, jnp.asarray(q), k=10)
        processed.append(int(res.ranges_processed))
    assert np.mean(processed) < cc.n_ranges  # pruning engaged on average


def test_budget_limits_work(cc):
    rng = np.random.default_rng(4)
    q = rng.normal(0, 1, size=32).astype(np.float32)
    res = anytime_mips(cc, jnp.asarray(q), k=10, budget_candidates=600,
                       safe_stop=False)
    assert int(res.candidates_scored) <= 600 + cc.capacity  # one range overshoot


def test_anytime_quality_monotone(candidates, cc):
    rng = np.random.default_rng(5)
    gains = []
    for _ in range(6):
        q = rng.normal(0, 1, size=32).astype(np.float32)
        oid, _ = _brute_topk(candidates, q, 10)
        lo = anytime_mips(cc, jnp.asarray(q), k=10, max_ranges=1, safe_stop=False)
        hi = anytime_mips(cc, jnp.asarray(q), k=10)
        rec_lo = len(set(np.asarray(lo.ids).tolist()) & set(oid)) / 10
        rec_hi = len(set(np.asarray(hi.ids).tolist()) & set(oid)) / 10
        gains.append(rec_hi - rec_lo)
    assert np.mean(gains) >= 0


def test_multi_interest_query(cc, candidates):
    rng = np.random.default_rng(6)
    q = rng.normal(0, 1, size=(4, 32)).astype(np.float32)  # MIND interests
    res = anytime_mips(cc, jnp.asarray(q), k=5)
    oid, osc = _brute_topk(candidates, q, 5)
    np.testing.assert_allclose(np.sort(np.asarray(res.scores)), np.sort(osc), rtol=1e-5)
