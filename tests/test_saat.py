"""Impact-ordered SAAT baseline (JASS) correctness + locality mechanism."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.clustered_index import build_index
from repro.core.oracle import exhaustive_topk
from repro.core.reorder import arrange
from repro.core.saat import build_impact_index, saat_query


@pytest.fixture(scope="module")
def impact_index(index):
    return build_impact_index(index)


def test_segments_cover_all_postings(index, impact_index):
    assert impact_index.docs.shape[0] == index.nnz
    lens = impact_index.seg_end - impact_index.seg_start
    assert int(lens.sum()) == index.nnz
    # Impacts constant within a segment.
    for s in range(0, impact_index.seg_term.shape[0], 211):
        lo, hi = int(impact_index.seg_start[s]), int(impact_index.seg_end[s])
        assert np.all(impact_index.imps[lo:hi] == impact_index.seg_impact[s])


def test_jass_exhaustive_matches_oracle(index, impact_index, queries):
    for q in queries[:6]:
        res = saat_query(impact_index, q, k=10, rho=None)
        _, osc = exhaustive_topk(index, q, 10)
        assert sorted(res.scores.tolist(), reverse=True) == sorted(
            osc.tolist(), reverse=True
        )


def test_jass_budget_respected(impact_index, queries):
    for q in queries[:6]:
        res = saat_query(impact_index, q, k=10, rho=500)
        # Budget may overshoot by at most one segment (checked at boundaries).
        assert res.segments_processed >= 1
        prev = saat_query(impact_index, q, k=10, rho=10**9)
        assert res.postings_processed <= prev.postings_processed


def test_reordering_improves_accumulator_locality(corpus, queries):
    """Paper §5.2 mechanism: reordered docids -> fewer accumulator rows."""
    idx_rand = build_index(
        corpus, arrangement=arrange(corpus, strategy="random", seed=0)
    )
    idx_reord = build_index(
        corpus,
        arrangement=arrange(corpus, n_ranges=8, strategy="clustered_bp", bp_rounds=4),
    )
    ii_rand = build_impact_index(idx_rand)
    ii_reord = build_impact_index(idx_reord)
    rows_rand, rows_reord = 0, 0
    rho = corpus.n_docs // 10  # the paper's JASS-A setting (10% of docs)
    for q in queries:
        rows_rand += saat_query(ii_rand, q, rho=rho).rows_touched
        rows_reord += saat_query(ii_reord, q, rho=rho).rows_touched
    assert rows_reord <= rows_rand
