"""Range-sharded retrieval: shard planner invariants + bitwise parity.

The contract under test (DESIGN.md §4): partitioning the clustered index
along range boundaries and merging per-shard heaps is *bitwise* identical
to the single-device ``device_traverse`` whenever budgets are exhaustive —
same doc ids, scores, and tie-breaks — and per-shard ``exit_reasons`` /
``fidelity_bound`` surface correctly when a shard hits its budget.

The multi-device (shard_map mesh) variant runs in a subprocess with 4
forced host devices; in-process tests pin the single-device vmap path
(device count must stay 1 here, per the dry-run contract).
"""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest
from differential import assert_sharded_matches_engine

from repro.core.clustered_index import (
    BLOCK,
    balance_range_shards,
    build_index,
    shard_device_index,
)
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine
from repro.data.synth import make_corpus, make_query_log
from repro.serving import (
    BucketSpec,
    MicroBatchServer,
    ShardedBatchEngine,
    ShardedEngine,
    ShardedSlaBudgeter,
)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
INT32_MAX = 2**31 - 1


def _small_setup(seed: int, n_ranges: int, k: int = 5):
    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=seed
    )
    idx = build_index(corpus, n_ranges=n_ranges, strategy="clustered")
    eng = Engine(idx, k=k)
    log = make_query_log(corpus, n_queries=10, seed=seed + 1)
    return idx, eng, [log.terms[i] for i in range(log.n_queries)]


# ------------------------------------------------------------- shard planner


def test_balance_range_shards_partitions_and_balances():
    mass = np.asarray([10, 10, 10, 10, 10, 10, 10, 10])
    cuts = balance_range_shards(mass, 4)
    assert cuts.tolist() == [0, 2, 4, 6, 8]
    # Skewed mass: heavy ranges get their own shard, cuts stay monotone.
    mass = np.asarray([100, 1, 1, 1, 1, 1, 1, 100])
    cuts = balance_range_shards(mass, 3)
    assert cuts[0] == 0 and cuts[-1] == 8
    assert np.all(np.diff(cuts) >= 1)
    with pytest.raises(ValueError):
        balance_range_shards(mass, 9)  # more shards than ranges
    with pytest.raises(ValueError):
        balance_range_shards(mass, 0)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_shard_device_index_remaps_to_local_coordinates(n_shards):
    idx, _, _ = _small_setup(seed=0, n_ranges=6)
    shards = shard_device_index(idx, n_shards)
    assert len(shards) == n_shards
    # Shards tile the range space and the docid space contiguously.
    assert shards[0].range_lo == 0 and shards[-1].range_hi == idx.n_ranges
    for a, b in zip(shards, shards[1:]):
        assert a.range_hi == b.range_lo
        assert a.doc_base + a.n_docs == b.doc_base
    assert sum(sh.postings for sh in shards) == idx.nnz
    for sh in shards:
        # Local coordinates: docs in [0, n_docs), range_starts rebased.
        assert sh.docs.min(initial=0) >= 0
        assert sh.docs.max(initial=0) < max(sh.n_docs, 1)
        np.testing.assert_array_equal(
            sh.range_starts,
            idx.range_starts[sh.range_lo : sh.range_hi] - sh.doc_base,
        )
        np.testing.assert_array_equal(
            sh.bounds_dense, idx.bounds_dense[:, sh.range_lo : sh.range_hi]
        )
        # blk_map round-trip: every owned global block's postings survive.
        owned = np.nonzero(sh.blk_map >= 0)[0]
        assert owned.shape[0] == sh.blk_len.shape[0]
        for g in owned[:: max(1, owned.shape[0] // 8)]:
            loc = sh.blk_map[g]
            s_g, l_g = int(idx.blk_start[g]), int(idx.blk_len[g])
            s_l = int(sh.blk_start[loc])
            np.testing.assert_array_equal(
                sh.docs[s_l : s_l + l_g] + sh.doc_base,
                idx.docs[s_g : s_g + l_g],
            )
            np.testing.assert_array_equal(
                sh.impacts[s_l : s_l + l_g], idx.impacts[s_g : s_g + l_g]
            )


def test_shard_mass_balance_is_reasonable():
    idx, _, _ = _small_setup(seed=3, n_ranges=8)
    shards = shard_device_index(idx, 4)
    masses = np.asarray([sh.postings for sh in shards], np.float64)
    # Greedy prefix cuts at range granularity: no shard carries more than
    # the ideal share plus one whole range's worth of postings.
    per_range = np.bincount(idx.blk_range, weights=idx.blk_len, minlength=idx.n_ranges)
    assert masses.max() <= masses.sum() / 4 + per_range.max()


# ---------------------------------------------------- bitwise parity (vmap)


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("safe_stop", [True, False])
def test_sharded_matches_single_device_bitwise(n_shards, safe_stop):
    """Exhaustive budgets: merged shard heaps == single-device top-k, bitwise."""
    _, eng, queries = _small_setup(seed=7, n_ranges=6)
    se = ShardedEngine(eng, n_shards, use_mesh=False)
    assert_sharded_matches_engine(
        se, [eng.plan(q) for q in queries], safe_stop=safe_stop
    )


def test_sharded_batch_engine_parity_across_buckets():
    """ShardedBatchEngine over ragged batches == looped single-device."""
    _, eng, queries = _small_setup(seed=11, n_ranges=6)
    stripped = [q[q >= 0] for q in queries]
    fat = np.unique(np.concatenate(stripped))
    ragged = [stripped[0][:1]] + stripped + [fat, fat[::2]]
    se = ShardedEngine(eng, 4, use_mesh=False)
    beng = ShardedBatchEngine(se, BucketSpec(max_batch=4))
    plans = beng.plan_many(ragged)
    results = beng.run_batch(plans)
    assert len(beng.compiled_shapes) >= 2
    for plan, r in zip(plans, results):
        single = eng.traverse(plan)
        sids, svals = eng.topk_docs(single.state)
        assert r.doc_ids.tolist() == sids.tolist()
        assert r.scores.tolist() == svals.tolist()


def test_single_shard_reduces_to_engine():
    _, eng, queries = _small_setup(seed=13, n_ranges=4)
    se = ShardedEngine(eng, 1, use_mesh=False)
    assert_sharded_matches_engine(se, [eng.plan(q) for q in queries[:4]])


# ------------------------------------------------- budgets and exit reasons


def test_per_shard_budget_exit_reasons_surface():
    """A starved shard reports "budget"; its peers run to exhaustion."""
    _, eng, queries = _small_setup(seed=17, n_ranges=6)
    se = ShardedEngine(eng, 4, use_mesh=False)
    star = int(np.argmax(se.r_loc))  # needs >= 2 ranges to bind mid-shard
    assert se.r_loc[star] >= 2
    beng = ShardedBatchEngine(se, BucketSpec(max_batch=4))
    plans = beng.plan_many(queries[:4])
    budgets = np.full((4, se.n_shards), INT32_MAX, np.int64)
    budgets[:, star] = 1
    results = beng.run_batch(plans, budget_postings=budgets, safe_stop=False)
    free = beng.run_batch(plans, safe_stop=False)
    starved_seen = False
    for r, f in zip(results, free):
        for s, reason in enumerate(r.shard_exit_reasons):
            if s != star:
                assert reason == "exhausted"
        if r.shard_exit_reasons[star] == "budget":
            starved_seen = True
            assert r.shard_ranges[star] < se.r_loc[star]
            assert not r.exact or r.fidelity_bound < int(r.scores[-1])
        # Starving one shard never perturbs the other shards' work.
        np.testing.assert_array_equal(
            np.delete(r.shard_postings, star), np.delete(f.shard_postings, star)
        )
    assert starved_seen


def test_fidelity_bound_certifies_missed_documents():
    """Budget exits: every missed oracle doc scores <= the reported bound."""
    idx, eng, queries = _small_setup(seed=19, n_ranges=6)
    # Heavy union queries: enough postings per shard that a 2-block budget
    # actually binds (light queries never leave the per-shard BLOCK floor).
    stripped = [q[q >= 0] for q in queries]
    fat = np.unique(np.concatenate(stripped))
    queries = [fat, fat[::2], fat[1::2], fat[::3]] + stripped[:4]
    se = ShardedEngine(eng, 4, use_mesh=False)
    beng = ShardedBatchEngine(se, BucketSpec(max_batch=4))
    plans = beng.plan_many(queries)
    results = beng.run_batch(
        plans, budget_postings=np.full(len(plans), 2 * BLOCK), safe_stop=False
    )
    budgeted = 0
    for q, r in zip(queries, results):
        oid, osc = exhaustive_topk(idx, q, eng.k)
        if "budget" in r.shard_exit_reasons:
            budgeted += 1
        got = set(r.doc_ids.tolist())
        theta = int(r.scores[-1]) if r.scores.shape[0] else 0
        for d, s in zip(oid.tolist(), osc.tolist()):
            if d not in got:
                assert s <= max(r.fidelity_bound, theta), (d, s, r)
        if r.exact:
            assert got == set(oid.tolist()[: len(got)]) or r.scores.shape[0] == 0
    assert budgeted > 0  # the knob actually bound somewhere


def test_exact_requires_full_list_under_budget_exit():
    """A budget-exited query with fewer than k results is never 'exact'.

    With an under-filled list *any* unprocessed document belongs in the
    top-k, so the fidelity bound alone must not certify exactness.
    """
    _, eng, queries = _small_setup(seed=31, n_ranges=6, k=50)
    se = ShardedEngine(eng, 4, use_mesh=False)
    beng = ShardedBatchEngine(se, BucketSpec(max_batch=4))
    plans = beng.plan_many(queries)
    budgets = np.ones((len(plans), se.n_shards), np.int64)  # 1 range per shard
    results = beng.run_batch(plans, budget_postings=budgets, safe_stop=False)
    underfull = 0
    for r in results:
        if "budget" in r.shard_exit_reasons and r.doc_ids.shape[0] < eng.k:
            assert not r.exact, r
            underfull += 1
    assert underfull > 0  # the scenario actually occurred


def test_global_budget_splits_proportionally():
    _, eng, _ = _small_setup(seed=23, n_ranges=6)
    se = ShardedEngine(eng, 3, use_mesh=False)
    split = se.split_postings_budget([9000, INT32_MAX, 0])
    assert split.shape == (3, 3)
    # Explicit zero stays zero on every shard (same meaning as unsharded).
    assert np.all(split[2] == 0)
    # Proportional to mass, ceil'd, floored at one block.
    assert int(split[0].sum()) >= 9000
    assert np.all(split[0] >= BLOCK)
    np.testing.assert_allclose(
        split[0] / split[0].sum(), se.mass / se.mass.sum(), atol=0.05
    )
    assert np.all(split[1] == INT32_MAX)  # unbounded stays unbounded
    ranges = se.split_range_budget([3, 0, INT32_MAX])
    assert np.all(ranges[0] >= 1) and int(ranges[0].sum()) >= 3
    assert np.all(ranges[1] == 0) and np.all(ranges[2] == INT32_MAX)


# --------------------------------------------------------- SLA + request loop


def test_sharded_sla_budgeter_per_shard_ewma():
    bud = ShardedSlaBudgeter(sla_ms=10.0, rate=100.0, n_shards=3)
    b0 = bud.budgets(2)
    assert b0.shape == (2, 3) and np.all(b0 == b0[0, 0])
    # Unequal shard throughput -> unequal caps next round.
    bud.observe_sharded(10.0, np.asarray([10_000, 1_000, 100]), n=2)
    b1 = bud.budgets(1)[0]
    assert b1[0] > b1[1] > b1[2] >= bud.floor
    # Shared Eq. (7) feedback: an SLA miss shrinks every shard's cap.
    alpha0 = bud.policy.alpha
    bud.observe_sharded(100.0, np.asarray([1, 1, 1]), n=1)
    assert bud.policy.alpha > alpha0
    assert np.all(bud.budgets(1)[0] <= b1)
    # Floor survives a miss streak.
    for _ in range(50):
        bud.observe_sharded(1e5, np.asarray([1, 1, 1]), n=1)
    assert np.all(bud.budgets(1)[0] >= bud.floor)


def test_microbatch_server_over_sharded_engine():
    """The request loop runs unchanged over the sharded (batch x shard) path."""
    _, eng, queries = _small_setup(seed=29, n_ranges=6)
    se = ShardedEngine(eng, 2, use_mesh=False)
    beng = ShardedBatchEngine(se, BucketSpec(max_batch=4))
    budgeter = ShardedSlaBudgeter(sla_ms=1e9, n_shards=2)
    server = MicroBatchServer(beng, budgeter, max_batch=4)
    served = server.replay(queries, batch_size=4)
    assert sorted(s.rid for s in served) == list(range(len(queries)))
    assert server.pending == 0
    for s in served:
        single = eng.traverse(eng.plan(queries[s.rid]))
        sids, svals = eng.topk_docs(single.state)
        assert s.result.doc_ids.tolist() == sids.tolist()
        assert s.result.scores.tolist() == svals.tolist()
    # Per-shard EWMAs were fed by the server's observe_sharded hook.
    assert not np.all(budgeter.rates == 100.0)


# ------------------------------------------------- multi-device (shard_map)

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.core.clustered_index import build_index
from repro.core.range_daat import Engine
from repro.data.synth import make_corpus, make_query_log
from repro.serving import BucketSpec, ShardedBatchEngine, ShardedEngine

assert jax.device_count() == 4, jax.device_count()
corpus = make_corpus(n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=7)
idx = build_index(corpus, n_ranges=6, strategy="clustered")
eng = Engine(idx, k=5)
log = make_query_log(corpus, n_queries=8, seed=8)
queries = [log.terms[i] for i in range(log.n_queries)]

se = ShardedEngine(eng, 4)  # auto: 4 devices -> shard_map mesh path
assert se.mesh is not None
beng = ShardedBatchEngine(se, BucketSpec(max_batch=4))
plans = beng.plan_many(queries)
ok = 0
for plan, r in zip(plans, beng.run_batch(plans)):
    single = eng.traverse(plan)
    sids, svals = eng.topk_docs(single.state)
    assert r.doc_ids.tolist() == sids.tolist(), (r.doc_ids, sids)
    assert r.scores.tolist() == svals.tolist()
    assert r.exact
    ok += 1

# Exit reasons cross the mesh too: starve one shard, flags come back per shard.
star = int(np.argmax(se.r_loc))
budgets = np.full((len(plans), 4), 2**31 - 1, np.int64)
budgets[:, star] = 1
starved = beng.run_batch(plans, budget_postings=budgets, safe_stop=False)
assert any(r.shard_exit_reasons[star] == "budget" for r in starved)
print("SHARDED_MESH_OK", ok)
"""


@pytest.mark.slow
def test_four_shard_mesh_matches_single_device_bitwise():
    """Acceptance: 4-shard shard_map engine == single-device top-k, bitwise."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT,
        timeout=900,
    )
    assert "SHARDED_MESH_OK 8" in out.stdout, out.stdout + out.stderr
