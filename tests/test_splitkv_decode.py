"""Split-KV decode (§Perf cell A) must match the baseline decode exactly.

Correctness of: partial-softmax merge across seq chunks, cache insertion on
the owning rank, row-sharded projections, MLA absorbed matmuls, and the
full-grid MoE EP — validated on an 8-device subprocess mesh (2 data x 4
model) against the batch-sharded baseline, in fp32.
"""

from __future__ import annotations

import os
import subprocess
import sys

import pytest


_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models.attention import GQAConfig, MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import (LMConfig, init_lm, init_cache,
                                      lm_decode_step)
from repro.distributed.sharding import ShardCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")

def check(cfg, name):
    p = init_lm(jax.random.key(0), cfg)
    B, S = 4, 32
    toks = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab)
    # Warm the cache with 8 tokens via baseline prefill, then decode 1.
    cache = init_cache(cfg, B, S)
    _, cache = lm_decode_step(p, toks, cache, jnp.int32(0), cfg)
    nxt = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab)
    ref_logits, _ = lm_decode_step(p, nxt, cache, jnp.int32(8), cfg)

    got_logits, new_cache = lm_decode_step(
        p, nxt, cache, jnp.int32(8), cfg, shard_ctx=ctx, decode_impl="split_kv"
    )
    err = float(jnp.max(jnp.abs(got_logits - ref_logits)))
    rel = err / (float(jnp.max(jnp.abs(ref_logits))) + 1e-9)
    assert rel < 2e-4, (name, err, rel)
    # One more step to exercise cache round-trip through the split layout.
    nxt2 = jax.random.randint(jax.random.key(3), (B, 1), 0, cfg.vocab)
    ref2, _ = lm_decode_step(
        p, nxt2, jax.tree.map(lambda a: a, new_cache), jnp.int32(9), cfg,
        shard_ctx=ctx, decode_impl="split_kv",
    )
    base_logits, base_cache = lm_decode_step(p, nxt, cache, jnp.int32(8), cfg)
    ref2_base, _ = lm_decode_step(p, nxt2, base_cache, jnp.int32(9), cfg)
    rel2 = float(jnp.max(jnp.abs(ref2 - ref2_base))) / (
        float(jnp.max(jnp.abs(ref2_base))) + 1e-9)
    assert rel2 < 2e-4, (name, rel2)
    print(name, "OK", rel, rel2)

gqa_cfg = LMConfig(
    name="t", n_layers=2, d_model=64, vocab=128,
    attn=GQAConfig(d_model=64, n_heads=8, n_kv_heads=4, head_dim=8, qk_norm=True),
    d_ff=96, max_seq=32, dtype=jnp.float32, attn_chunk=16, remat=False,
)
check(gqa_cfg, "gqa")

# Seq-parallel prefill (chunk == per-rank slice) must match one-shot prefill.
cfgp = gqa_cfg
p = init_lm(jax.random.key(7), cfgp)
B, S = 4, 32
toks = jax.random.randint(jax.random.key(8), (B, S), 0, cfgp.vocab)
ch = S // 4  # n_model = 4
n_pref = 3 * ch  # prefill 3 of 4 chunks, decode into the last slice
cache_ref = init_cache(cfgp, B, S)
ref_logits, cache_ref = lm_decode_step(
    p, toks[:, :n_pref], cache_ref, jnp.int32(0), cfgp
)
cache_sp = init_cache(cfgp, B, S)
for c in range(n_pref // ch):
    sp_logits, cache_sp = lm_decode_step(
        p, toks[:, c*ch:(c+1)*ch], cache_sp, jnp.int32(c*ch), cfgp,
        shard_ctx=ctx, decode_impl="split_kv",
    )
rel = float(jnp.max(jnp.abs(sp_logits[:, -1] - ref_logits[:, -1]))) / (
    float(jnp.max(jnp.abs(ref_logits[:, -1]))) + 1e-9)
assert rel < 2e-4, ("prefill", rel)
# And the split cache must continue correctly into split decode.
nxt = jax.random.randint(jax.random.key(9), (B, 1), 0, cfgp.vocab)
d_ref, _ = lm_decode_step(p, nxt, cache_ref, jnp.int32(n_pref), cfgp)
d_sp, _ = lm_decode_step(p, nxt, cache_sp, jnp.int32(n_pref), cfgp,
                         shard_ctx=ctx, decode_impl="split_kv")
rel2 = float(jnp.max(jnp.abs(d_sp - d_ref))) / (float(jnp.max(jnp.abs(d_ref))) + 1e-9)
assert rel2 < 2e-4, ("prefill->decode", rel2)
print("gqa-prefill OK", rel, rel2)

mla_moe_cfg = LMConfig(
    name="t2", n_layers=2, d_model=64, vocab=128,
    attn=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                   qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    d_ff=96, moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1),
    n_dense_layers=1, max_seq=32, dtype=jnp.float32, attn_chunk=16, remat=False,
)
check(mla_moe_cfg, "mla+moe")
print("SPLITKV_ALL_OK")
"""


@pytest.mark.slow
def test_splitkv_decode_matches_baseline():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT, timeout=1200,
    )
    assert "SPLITKV_ALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
