"""End-to-end behaviour tests for the paper's system.

Full pipeline: synthetic corpus -> topical clustering + BP reordering ->
cluster-skipping index -> BoundSum range ordering -> anytime traversal under
each §6 termination policy, validated against the exhaustive oracle. SLA
decision logic is additionally exercised with a deterministic fake clock so
compliance assertions do not depend on container timing noise.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.anytime import (
    Fixed,
    Overshoot,
    Predictive,
    Reactive,
    Undershoot,
    run_query_anytime,
)
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk

# Deterministic clock shared with the observability substrate (repro.obs):
# one definition of a fake second for every SLA/latency test.
from repro.obs import FakeClock


@pytest.mark.parametrize(
    "policy",
    [None, Fixed(3), Overshoot(), Undershoot(2.0), Predictive(1.0), Reactive()],
)
def test_every_policy_runs_end_to_end(engine, queries, policy):
    plan = engine.plan(queries[0])
    res = run_query_anytime(engine, plan, policy=policy, budget_ms=50.0)
    assert res.ranges_processed >= 0
    assert res.exit_reason in ("exhausted", "safe", "policy")
    assert np.all(np.diff(res.scores) <= 0)  # sorted descending


def test_unlimited_budget_is_rank_safe(engine, index, queries):
    for q in queries[:5]:
        plan = engine.plan(q)
        res = run_query_anytime(engine, plan, policy=None)
        oid, osc = exhaustive_topk(index, q, engine.k)
        # Exact ranking match (deterministic docid tie-break on both sides).
        assert res.doc_ids.tolist() == oid.tolist()
        assert res.scores.tolist() == osc.tolist()


def test_undershoot_never_violates_with_bounded_range_time(engine, queries):
    """Undershoot(t_max) must finish within B when ranges cost <= t_max."""
    clock = FakeClock(dt=0.0005)  # every clock call costs 0.5 ms
    plan = engine.plan(queries[1])
    # Each range costs ~2 clock calls = ~1 ms << t_max = 5 ms.
    res = run_query_anytime(
        engine, plan, policy=Undershoot(5.0), budget_ms=20.0, clock=clock
    )
    assert res.elapsed_ms <= 25.0  # B plus measurement slack, never a range over


def test_predictive_terminates_under_pressure(engine, queries):
    clock = FakeClock(dt=0.004)  # 4 ms per clock call -> ranges look slow
    plan = engine.plan(queries[1])
    res = run_query_anytime(
        engine, plan, policy=Predictive(1.0), budget_ms=30.0, clock=clock
    )
    assert res.exit_reason in ("policy", "safe", "exhausted")
    assert res.ranges_processed < plan.order_host.shape[0] or res.exit_reason != "policy"


def test_reactive_feedback_loop_adapts(engine, queries):
    pol = Reactive(alpha=1.0, beta=1.5, q=0.01)
    for q in queries[:6]:
        plan = engine.plan(q)
        run_query_anytime(engine, plan, policy=pol, budget_ms=0.5)
    assert len(pol.trace) == 6
    assert pol.alpha != 1.0  # feedback moved alpha


def test_anytime_quality_improves_with_ranges(engine, index, queries):
    """Fig 7 behaviour: more ranges processed -> higher RBO vs exhaustive."""
    mean_rbo = {1: [], 4: [], 10**9: []}
    for q in queries:
        oid, _ = exhaustive_topk(index, q, 10)
        plan = engine.plan(q)
        for n in mean_rbo:
            res = engine.traverse(plan, max_ranges=n, safe_stop=n == 10**9)
            ids, _ = engine.topk_docs(res.state)
            mean_rbo[n].append(rbo(ids.tolist(), oid.tolist(), phi=0.8))
    m1 = float(np.mean(mean_rbo[1]))
    m4 = float(np.mean(mean_rbo[4]))
    mall = float(np.mean(mean_rbo[10**9]))
    assert m1 <= m4 + 1e-9 <= mall + 1e-9
    assert mall >= 0.999  # unlimited == exhaustive
