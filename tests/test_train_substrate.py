"""Optimizer, trainer loop, checkpointing, fault tolerance."""

from __future__ import annotations

import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedule import cosine_with_warmup
from repro.train.checkpoint import Checkpointer
from repro.train.trainer import Trainer, TrainerConfig, make_train_step, zero1_spec
from jax.sharding import PartitionSpec as P


def _quadratic_loss(params, batch):
    return jnp.sum((params["w"] - batch["target"]) ** 2)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.zeros((8,), jnp.float32)}
    state = init_opt_state(params, AdamWConfig(lr=0.2, weight_decay=0.0))
    batch = {"target": jnp.ones((8,), jnp.float32)}
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(120):
        g = jax.grad(_quadratic_loss)(params, batch)
        params, state, _ = adamw_update(params, g, state, cfg, 0.2)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=0.05)


@pytest.mark.parametrize("dtype", ["fp32", "bf16", "int8"])
def test_moment_dtypes_converge(dtype):
    params = {"w": jnp.zeros((300,), jnp.float32)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, moment_dtype=dtype)
    state = init_opt_state(params, cfg)
    batch = {"target": jnp.full((300,), 2.0, jnp.float32)}
    for _ in range(150):
        g = jax.grad(_quadratic_loss)(params, batch)
        params, state, _ = adamw_update(params, g, state, cfg, 0.1)
    np.testing.assert_allclose(np.asarray(params["w"]), 2.0, atol=0.15)


def test_int8_moment_state_shapes_preserve_leading_dims():
    params = {"w": jnp.zeros((6, 512), jnp.float32)}
    state = init_opt_state(params, AdamWConfig(moment_dtype="int8"))
    assert state["m"]["w"]["q"].shape == (6, 2, 256)
    assert state["m"]["w"]["scale"].shape == (6, 2, 1)


def test_zero1_spec_adds_data_axis():
    s = zero1_spec(P(None, "model"), (1024, 64), 16, ("data",))
    assert s == P(("data",), "model")
    s2 = zero1_spec(P("model", None), (64, 1000), 16, ("data",))  # 1000 % 16 != 0
    assert s2 == P("model", None)


def test_schedule_warmup_and_decay():
    lr0 = float(cosine_with_warmup(jnp.int32(0), peak=1.0, warmup=10, total=100))
    lr10 = float(cosine_with_warmup(jnp.int32(10), peak=1.0, warmup=10, total=100))
    lr100 = float(cosine_with_warmup(jnp.int32(100), peak=1.0, warmup=10, total=100))
    assert lr0 < 0.2 and abs(lr10 - 1.0) < 1e-6 and lr100 < 0.2


def test_microbatch_accumulation_equals_big_batch():
    params = {"w": jnp.ones((4,), jnp.float32)}
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0)

    def loss(p, b):
        return jnp.mean((jnp.dot(b["x"], p["w"]) - b["y"]) ** 2)

    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 4)).astype(np.float32)
    y = rng.normal(size=(8,)).astype(np.float32)

    step1 = make_train_step(loss, cfg, accum=1)
    step2 = make_train_step(loss, cfg, accum=2)
    s0 = init_opt_state(params, cfg)
    p1, _, m1 = jax.jit(step1)(params, s0, {"x": x, "y": y})
    s0 = init_opt_state(params, cfg)
    micro = {"x": x.reshape(2, 4, 4), "y": y.reshape(2, 4)}
    p2, _, m2 = jax.jit(step2)(params, s0, micro)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-5)


# ------------------------------------------------------------- checkpointing


def _tiny_trainer(tmpdir, total_steps=8, ckpt_every=2):
    params = {"w": jnp.zeros((4,), jnp.float32)}

    def loss(p, b):
        return jnp.sum((p["w"] - b["target"]) ** 2)

    def data_fn(step):
        return {"target": jnp.full((4,), float(step % 3), jnp.float32)}

    return Trainer(
        loss,
        params,
        TrainerConfig(
            total_steps=total_steps,
            checkpoint_every=ckpt_every,
            log_every=1,
            lr=0.05,
        ),
        data_fn,
        checkpointer=Checkpointer(str(tmpdir), keep_last=2),
    )


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=3)
    state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ck.save(state, step=5, blocking=True)
    out = ck.restore_latest()
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(state["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.asarray(state["b"]["c"]))


def test_checkpoint_gc_keeps_last(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save({"x": jnp.ones(2) * s}, step=s, blocking=True)
    assert ck.list_steps() == [3, 4]


def test_checkpoint_atomicity_partial_write_invisible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    # Simulate a crash mid-write: directory without manifest.
    os.makedirs(tmp_path / "step_00000009", exist_ok=True)
    assert ck.list_steps() == []
    assert ck.restore_latest() is None


def test_trainer_runs_and_resumes(tmp_path):
    t1 = _tiny_trainer(tmp_path, total_steps=4, ckpt_every=2)
    out1 = t1.run(install_signal_handlers=False)
    assert out1["exit"] == "completed" and out1["last_step"] == 4

    # New trainer restores from step 4 and continues to 6.
    t2 = _tiny_trainer(tmp_path, total_steps=6, ckpt_every=2)
    out2 = t2.run(install_signal_handlers=False)
    assert out2["last_step"] == 6
    first_logged = out2["history"][0]["step"]
    assert first_logged >= 5  # resumed, did not replay from 0


def test_trainer_preemption_checkpoints_and_exits(tmp_path):
    t = _tiny_trainer(tmp_path, total_steps=100, ckpt_every=1000)
    t._preempted = False

    # Trip the preemption flag after the 3rd step via the data hook.
    orig = t.data_fn

    def data_fn(step):
        if step == 3:
            t._handle_preemption(signal.SIGTERM, None)
        return orig(step)

    t.data_fn = data_fn
    out = t.run(install_signal_handlers=False)
    assert out["exit"] == "preempted"
    ck = Checkpointer(str(tmp_path))
    assert ck.list_steps(), "preemption must leave a checkpoint"


def test_elastic_restore_reshards(tmp_path):
    """Restore onto a different sharding (elastic DP width change)."""
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(state, step=1, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding

    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = ck.restore(1, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    assert out["w"].sharding == sh["w"]
