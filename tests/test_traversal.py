"""Traversal correctness: rank-safety, pruning soundness, anytime behavior."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_scores, exhaustive_topk
from repro.core.range_daat import Engine
from repro.core.anytime import run_query_anytime, Fixed


def _score_multiset(state):
    ids = np.asarray(state.ids)
    vals = np.asarray(state.vals)
    return sorted(vals[ids >= 0].tolist(), reverse=True)


def test_safe_traversal_matches_oracle(engine, index, queries):
    for q in queries:
        plan = engine.plan(q)
        res = engine.traverse(plan)
        _, osc = exhaustive_topk(index, q, engine.k)
        assert _score_multiset(res.state) == sorted(osc.tolist(), reverse=True)


def test_safe_traversal_k100(index, queries):
    eng = Engine(index, k=100)
    for q in queries[:4]:
        res = eng.traverse(eng.plan(q))
        _, osc = exhaustive_topk(index, q, 100)
        assert _score_multiset(res.state) == sorted(osc.tolist(), reverse=True)


def test_range_oblivious_also_safe(index, queries):
    """Docid-order traversal with global bounds must still be rank-safe."""
    eng = Engine(index, k=10, ordering="docid", bounds="global")
    for q in queries[:6]:
        res = eng.traverse(eng.plan(q))
        _, osc = exhaustive_topk(index, q, 10)
        assert _score_multiset(res.state) == sorted(osc.tolist(), reverse=True)


def test_no_block_pruning_still_safe(engine, index, queries):
    for q in queries[:4]:
        res = engine.traverse(engine.plan(q), prune_blocks=False)
        _, osc = exhaustive_topk(index, q, 10)
        assert _score_multiset(res.state) == sorted(osc.tolist(), reverse=True)


def test_budget_scores_never_exceed_truth(engine, index, queries):
    """Anytime (unsafe) exits return only true-or-partial scores."""
    for q in queries[:6]:
        plan = engine.plan(q)
        res = engine.traverse(plan, budget_postings=500, safe_stop=False)
        truth = exhaustive_scores(index, q)
        ids = np.asarray(res.state.ids)
        vals = np.asarray(res.state.vals)
        for d, v in zip(ids, vals):
            if d >= 0:
                assert v <= truth[d]


def test_budget_monotone_quality(engine, index, queries):
    """More budget -> same or better RBO vs exhaustive (on average)."""
    deltas = []
    for q in queries[:8]:
        plan = engine.plan(q)
        oid, _ = exhaustive_topk(index, q, 10)
        lo = engine.traverse(plan, budget_postings=300, safe_stop=False)
        hi = engine.traverse(plan, budget_postings=10**9)
        ids_lo, _ = engine.topk_docs(lo.state)
        ids_hi, _ = engine.topk_docs(hi.state)
        deltas.append(
            rbo(ids_hi.tolist(), oid.tolist()) - rbo(ids_lo.tolist(), oid.tolist())
        )
    assert np.mean(deltas) >= 0.0


def test_fixed_policy_limits_ranges(engine, queries):
    plan = engine.plan(queries[0])
    res = run_query_anytime(engine, plan, policy=Fixed(2), budget_ms=1e9)
    assert res.ranges_processed <= 2


def test_host_executor_matches_oracle_when_unlimited(engine, index, queries):
    for q in queries[:4]:
        plan = engine.plan(q)
        res = run_query_anytime(engine, plan, policy=None, budget_ms=float("inf"))
        oid, osc = exhaustive_topk(index, q, 10)
        assert sorted(res.scores.tolist(), reverse=True) == sorted(
            osc.tolist(), reverse=True
        )
        assert res.exit_reason in ("exhausted", "safe")


def test_boundsum_order_front_loads_mass(engine, index, queries):
    """BoundSum-first processing finds top-1 earlier than docid order."""
    wins = 0
    total = 0
    for q in queries:
        plan = engine.plan(q)
        oid, _ = exhaustive_topk(index, q, 1)
        if oid.size == 0:
            continue
        top_range = int(
            np.searchsorted(index.range_ends, oid[0], side="right")
        )
        pos_bs = int(np.nonzero(plan.order_host == top_range)[0][0])
        pos_docid = top_range
        total += 1
        if pos_bs <= pos_docid:
            wins += 1
    assert total > 0 and wins / total >= 0.5


def test_safe_exit_skips_work_vs_exhaustive(engine, queries):
    """Safe termination should usually process fewer than all ranges."""
    processed = []
    R = engine.index.n_ranges
    for q in queries:
        res = engine.traverse(engine.plan(q))
        processed.append(int(res.ranges_processed))
    assert min(processed) <= R  # sanity
    assert np.mean(processed) <= R
